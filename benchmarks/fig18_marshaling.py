"""Paper Fig. 18: the marshaling win — LiLAC vs naive library calls that
re-transfer/re-pack on every invocation, plus the data-plane extension:
the *shared plan-level cache*, where harnesses targeting the same (or a
downstream) format ride one cached buffer instead of repacking privately.

Three measurements per problem:

  per-backend win   cached vs cache-cleared iteration (the classic Fig. 18
                    curve) for each marshaling backend;
  shared-plan win   cost of bringing up a second backend on a data plane
                    already primed by the first (e.g. jnp.bcsr's
                    CSR->DENSE->BCSR path riding jnp.dense's DENSE buffer)
                    vs bringing it up on an empty plane;
  plan stats        per-(source, target-format) hit/miss/bytes-avoided
                    accounting straight from ``DataPlane.plan_stats()``.

CLI:
    python benchmarks/fig18_marshaling.py [--quick] [--reps N] [--out PATH]

``--quick`` is the CI smoke grid; ``--out`` writes the BENCH_*.json
perf-trajectory artifact the bench-smoke job uploads.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, naive_spmv_fn, problem_suite, sweep,
                               timeit, vec_for, write_json_report)
from repro import lilac

# jnp.dense primes the DENSE intermediate that jnp.bcsr's planned
# CSR->DENSE->BCSR8x128 path reuses; jnp.ell shares the CSR load.
BACKENDS = ("jnp.dense", "jnp.bcsr", "jnp.ell")


def _iterate(spmv, csr, vec, iters, clear=False):
    x = vec
    for _ in range(iters):
        if clear and spmv.cache is not None:
            spmv.cache.clear()
        y = spmv(csr.val, csr.col_ind, csr.row_ptr, x[: csr.shape[1]])
        x = jnp.pad(y, (0, max(0, csr.shape[1] - y.shape[0])))
    return x


def run(reps: int = 5, iters: int = 10, quick: bool = False,
        out: str | None = None) -> dict:
    suite = problem_suite(quick=quick)
    probs = list(suite) if quick else ["erdos_8k", "powerlaw_4k", "banded_8k"]
    report = {
        "benchmark": "fig18_marshaling",
        "quick": quick,
        "reps": reps,
        "iters": iters,
        "platform": jax.default_backend(),
        "backends": list(BACKENDS),
        "problems": {},
    }
    table = {}
    for prob_name in probs:
        csr = suite[prob_name]
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        prob_report = {"backends": {}, "shared_plan": {}}

        # -- classic Fig. 18: cached vs re-packed-every-call ----------------
        # bake=False throughout this benchmark: it characterizes the DATA
        # PLANE (per-call cache hits, repack-on-critical-path A/B via
        # cache.clear()), which a baked plan bypasses entirely — its
        # guards don't consult the cache, so clear() would stop meaning
        # "repack every call".  Dispatch economics live in
        # benchmarks/dispatch_overhead.py.
        for backend in BACKENDS:
            acc = lilac.compile(naive, mode="host", policy=backend,
                                bake=False)
            pair = sweep({
                "cached": lambda: _iterate(acc, csr, vec, iters),
                "repack_every_call": lambda: _iterate(acc, csr, vec, iters,
                                                      clear=True),
            }, reps=reps, warmup=1)
            t_marshal = pair["cached"]
            t_naive_m = pair["repack_every_call"]
            win = t_naive_m / t_marshal
            table[(prob_name, backend)] = win
            st = acc.cache.stats
            prob_report["backends"][backend] = {
                "t_cached_s": t_marshal,
                "t_repack_every_call_s": t_naive_m,
                "marshaling_win": win,
                # which kernel-schedule variant this backend's plan ran
                # with (None: default / untuned — the jnp.* backends)
                "schedule": (acc.last_schedules[0]
                             if acc.last_schedules else None),
                "cache": {"hits": st.hits, "misses": st.misses,
                          "bytes_avoided": st.bytes_avoided,
                          "seconds_avoided": st.recompute_seconds_avoided},
            }
            emit(f"fig18.{prob_name}.{backend}", t_marshal,
                 f"marshaling_win={win:.2f}x "
                 f"(cached {st.recompute_seconds_avoided:.3f}s of repack)")

        # -- shared plan-level cache: second backend rides the first --------
        def first_call_seconds(policy, plane):
            acc = lilac.compile(naive, mode="host", policy=policy,
                                cache=plane, bake=False)
            t = timeit(lambda: acc(csr.val, csr.col_ind, csr.row_ptr, vec),
                       reps=1, warmup=0)
            return t, acc

        plane_cold = lilac.DataPlane()
        t_cold, _ = first_call_seconds("jnp.bcsr", plane_cold)

        plane_shared = lilac.DataPlane()
        t_prime, _ = first_call_seconds("jnp.dense", plane_shared)
        t_shared, _ = first_call_seconds("jnp.bcsr", plane_shared)

        stats = plane_shared.plan_stats()
        bcsr_plan = stats.get("csr_binding->BCSR8x128", {})
        shared = {
            "t_bcsr_cold_plane_s": t_cold,
            "t_dense_prime_s": t_prime,
            "t_bcsr_on_primed_plane_s": t_shared,
            "shared_plan_win": t_cold / t_shared if t_shared else float("nan"),
            "bcsr_path": bcsr_plan.get("last_path", []),
            "bcsr_rode_cached_intermediate":
                bool(bcsr_plan.get("shared_prefix_hits", 0)),
            # per-entry ride accounting: how many planned paths entered at
            # a cached intermediate, and the bytes they never rebuilt —
            # the sharing structure the joint plan search prices at cost 0
            "rides": sum(s.get("rides", 0) for s in stats.values()),
            "shared_prefix_bytes": sum(s.get("shared_prefix_bytes", 0)
                                       for s in stats.values()),
            "plan_stats": stats,
        }
        prob_report["shared_plan"] = shared
        emit(f"fig18.{prob_name}.shared_plan", t_shared,
             f"win={shared['shared_plan_win']:.2f}x over cold plane; "
             f"path={'->'.join(shared['bcsr_path'])} "
             f"rides={shared['rides']} "
             f"shared_prefix_bytes={shared['shared_prefix_bytes']}")
        report["problems"][prob_name] = prob_report

    report["shared_plan_always_rides_intermediate"] = all(
        p["shared_plan"]["bcsr_rode_cached_intermediate"]
        for p in report["problems"].values())
    report["all_caches_hit"] = all(
        b["cache"]["hits"] > 0 and b["cache"]["bytes_avoided"] > 0
        for p in report["problems"].values()
        for b in p["backends"].values())
    if out:
        write_json_report(out, report)
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke grid: small problems, few reps")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--out", default="",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    reps = args.reps if args.reps is not None else (2 if args.quick else 5)
    run(reps=reps, iters=args.iters, quick=args.quick, out=args.out or None)


if __name__ == "__main__":
    main()
