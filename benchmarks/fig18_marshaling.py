"""Paper Fig. 18: LiLAC vs naive library calls WITHOUT marshaling — the
repack/invariant cache is cleared before every invocation, as if every call
re-transferred and re-tuned.  Run on the iterative apps where the matrix is
invariant (PageRank / CG / BFS analogues)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, naive_spmv_fn, problem_suite, timeit, vec_for
from repro import lilac


def run(reps: int = 5, iters: int = 10) -> dict:
    suite = problem_suite()
    out = {}
    for prob_name in ("erdos_8k", "powerlaw_4k", "banded_8k"):
        csr = suite[prob_name]
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)

        def iterate(spmv, clear=False):
            x = vec
            for _ in range(iters):
                if clear:
                    spmv.cache.clear()
                y = spmv(csr.val, csr.col_ind, csr.row_ptr,
                         x[: csr.shape[1]])
                x = jnp.pad(y, (0, max(0, csr.shape[1] - y.shape[0])))
            return x

        for backend in ("jnp.ell", "jnp.bcsr"):
            acc = lilac.compile(naive, mode="host", policy=backend)
            t_marshal = timeit(lambda: iterate(acc), reps=reps, warmup=1)
            t_naive_m = timeit(lambda: iterate(acc, clear=True),
                               reps=reps, warmup=1)
            win = t_naive_m / t_marshal
            out[(prob_name, backend)] = win
            emit(f"fig18.{prob_name}.{backend}", t_marshal * 1e6,
                 f"marshaling_win={win:.2f}x "
                 f"(cached {acc.cache.stats.recompute_seconds_avoided:.3f}s "
                 f"of repack per run)")
    return out


if __name__ == "__main__":
    run()
