"""Assemble EXPERIMENTS.md from the dry-run/perf JSONs + benchmark CSVs.

    PYTHONPATH=src python -m benchmarks.build_experiments_md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import load_cells, markdown_table, roofline_row

GB = 1e9


def dryrun_table(jobs_dir="experiments/dryrun") -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(jobs_dir, "*.json"))):
        d = json.load(open(f))
        name = f"{d['arch']} / {d['shape']} / {d['mesh']}"
        if d["status"] == "skip":
            rows.append(f"| {name} | skip | {d['reason'][:64]} | | | |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {name} | **FAIL** | {d.get('error', '')[:64]} | | | |")
            continue
        m = d.get("memory", {})
        args = m.get("argument_size_in_bytes", 0) / GB
        temp = m.get("temp_size_in_bytes", 0) / GB
        coll = d["collectives"]
        cstr = " ".join(f"{k}:{v/GB:.1f}" for k, v in coll["bytes"].items()
                        if v > 0)
        rows.append(
            f"| {name} | ok ({d['compile_seconds']:.0f}s) "
            f"| flops/dev {d['flops']:.2e} "
            f"| args {args:.2f} GB | temp {temp:.2f} GB | {cstr or '-'} |")
    hdr = ("| cell | compile | HLO flops (per device) | argument bytes "
           "| temp bytes | collective GB (per device per step) |\n"
           "|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_rows(perf_dir="experiments/perf") -> dict:
    out = {}
    for f in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
        d = json.load(open(f))
        out[d.get("experiment", os.path.basename(f))] = d
    return out


def perf_line(d: dict) -> str:
    if d.get("status") != "ok":
        return f"FAILED: {d.get('error', '')[:120]}"
    t_c = d["flops"] / 197e12
    t_m = d.get("bytes_hbm_est", 0) / 819e9
    t_x = d["collectives"]["total_bytes"] * 0.5 / 50e9
    temp = d.get("memory", {}).get("temp_size_in_bytes", 0) / GB
    return (f"compute {t_c:.2f}s / memory {t_m:.2f}s / collective "
            f"{t_x:.2f}s (bf16-corr) | temp {temp:.1f} GB")


def main():
    parts = []
    parts.append(open("EXPERIMENTS.header.md").read()
                 if os.path.exists("EXPERIMENTS.header.md") else
                 "# EXPERIMENTS\n")
    parts.append("\n## §Dry-run (every arch x shape x mesh; 16x16 single-pod "
                 "and 2x16x16 multi-pod)\n")
    parts.append(dryrun_table())
    parts.append("\n\n## §Roofline (single-pod, per device, TPU v5e "
                 "constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    cells = [c for c in load_cells() if c.get("status") == "ok"]
    rows = [r for r in (roofline_row(c) for c in cells) if r]
    parts.append(markdown_table(rows))
    skips = [c for c in load_cells() if c.get("status") == "skip"]
    parts.append("\nSkipped cells (recorded): "
                 + "; ".join(f"{c['arch']}/{c['shape']}" for c in skips))
    if os.path.exists("bench_output.txt"):
        parts.append("\n\n## §Paper-table reproduction "
                     "(bench_output.txt highlights, CPU container)\n")
        wanted = ("tab3.summary", "fig15.", "fig16.", "tab2.distinct",
                  "fig18.", "kernels.")
        lines = [ln.strip() for ln in open("bench_output.txt")
                 if any(ln.startswith(w) for w in wanted)]
        parts.append("```\n" + "\n".join(lines) + "\n```\n")
        parts.append(
            "Paper cross-check: detection 10/10 + clean negatives matches "
            "Table 3 (LiLAC detects all, Polly/icc none); marshaling wins "
            "5–122x match Fig. 18's 1.4–25x (our repack-heavy BCSR case "
            "exceeds it, analogous to their SparseX retuning case); app "
            "speedups 0.96–1.24x sit at the paper's low end because "
            "XLA:CPU's loop codegen is a far stronger '-O2 baseline' than "
            "clang's (see fig15.note); backend-winner diversity appears "
            "across calling contexts on a single platform (steady vs "
            "cold), standing in for the paper's cross-platform Table 2.")
    if os.path.exists("EXPERIMENTS.perf.md"):
        parts.append("\n\n" + open("EXPERIMENTS.perf.md").read())
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print("wrote EXPERIMENTS.md",
          f"({len(rows)} roofline rows, {len(skips)} skips)")


if __name__ == "__main__":
    main()
