"""Steady-state dispatch overhead: baked executable plans vs the jaxpr
interpreter vs hand-written ``jax.jit`` (the paper's "free at run time"
claim, §5).

The LiLAC pass must not tax the steady state: once detection, tuning and
marshaling are resolved, calling the compiled function should cost what a
hand-written ``jax.jit`` integration costs.  This benchmark measures, per
quick-suite problem:

  t_jit          hand-written baseline: ``jax.jit(naive)`` steady-state
  t_interpreter  the pre-plan dispatch path (``bake=False``): eqn-by-eqn
                 jaxpr interpretation + marshal-cache fingerprinting on
                 every call
  t_plan         baked-plan dispatch: guard check + one jitted call

and reports ``interpreter_vs_plan`` (how much baking buys end to end) and
``plan_vs_jit`` (how close to hand-written we land; target <= 1.3x).  The
*dispatch overhead* itself — what the framework adds AROUND the kernel —
is isolated by also timing the plan's raw jitted executable
(``t_kernel_s``): ``overhead_plan_s = t_plan - t_kernel`` is the guard
check + python wrapper (~µs), ``overhead_interpreter_s`` the eqn
interpretation + per-call fingerprinting the plan eliminates
(``dispatch_overhead_reduction`` is their ratio).  It also proves the
persistent plan cache end to end: a fresh LilacFunction over the same
program must reach a baked plan with ZERO ``Detector.detect`` calls
(``warm_start.detect_calls``).

CLI:
    python benchmarks/dispatch_overhead.py [--quick] [--reps N]
                                           [--out PATH] [--policy NAME]
                                           [--seed-only]

``--quick`` is the CI smoke grid; ``--seed-only`` just runs one resolving
call per problem to populate the persistent plan/autotune caches (the CI
test job uses it to hand bench-smoke a warm start) and writes no report.
"""
from __future__ import annotations

import argparse
import platform as _platform

import jax

from benchmarks.common import (emit, naive_spmv_fn, problem_suite, timeit,
                               vec_for, write_json_report)
from repro import lilac


def _spy_detect():
    """Count Detector.detect invocations (restored by the caller)."""
    from repro.core import detect as D

    calls = {"n": 0}
    real = D.Detector.detect

    def spy(self, *a, **kw):
        calls["n"] += 1
        return real(self, *a, **kw)

    D.Detector.detect = spy
    return calls, lambda: setattr(D.Detector, "detect", real)


def run(reps: int = 50, quick: bool = False, out: str | None = None,
        policy: str = "default", seed_only: bool = False) -> dict:
    suite = problem_suite(quick=quick)
    plat = jax.default_backend()
    report = {
        "benchmark": "dispatch_overhead",
        "quick": quick,
        "reps": reps,
        "platform": plat,
        "host": _platform.machine(),
        "policy": policy,
        "plan_cache": str(lilac.default_plan_cache_path()),
        "problems": {},
    }
    last = None
    for name, csr in suite.items():
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        a = (csr.val, csr.col_ind, csr.row_ptr, vec)
        last = (naive, a)

        if seed_only:
            fast = lilac.compile(naive, mode="host", policy=policy)
            fast(*a)
            fast(*a)
            emit(f"dispatch.{name}.seed", 0.0,
                 f"baked={fast.plan_info()['baked']}")
            continue

        t_jit = timeit(jax.jit(naive), *a, reps=reps)
        interp = lilac.compile(naive, mode="host", policy=policy,
                               bake=False)
        t_interp = timeit(interp, *a, reps=reps)
        fast = lilac.compile(naive, mode="host", policy=policy)
        fast(*a)                        # resolve + record + bake
        fast(*a)                        # first fast-path hit
        info = fast.plan_info()
        t_plan = timeit(fast, *a, reps=reps)
        # the kernel floor: the plan's raw jitted executable, no framework
        # around it — the difference to t_plan/t_interp is pure dispatch
        plan = fast.executable_plan(*a)
        t_kernel = (timeit(plan.jitted, *a, reps=reps)
                    if plan is not None else float("nan"))
        # shadow-rate-0 containment cost: the serve path (shadow-rate
        # compare + sampling hook) vs the raw plan dispatch it wraps —
        # what PR "fail-safe acceleration" added to every steady-state
        # dispatch.  Gated <= 2% of total dispatch time.
        if plan is not None:
            leaves, _ = jax.tree_util.tree_flatten((a, {}))
            un = plan.match_and_unwrap(plan.in_tree, leaves, plan.enabled)
            # the wrapper delta is sub-microsecond pure Python;
            # subtracting two ~half-millisecond kernel timings would
            # drown it in scheduler jitter, so stub the inner dispatch
            # to a constant and time the _serve_plan wrapper itself
            sentinel = fast._dispatch_plan(plan, un)
            micro_reps = max(reps, 200)
            try:
                fast._dispatch_plan = lambda p, l: sentinel
                t_inner = timeit(lambda: fast._dispatch_plan(plan, un),
                                 reps=micro_reps)
                t_serve = timeit(
                    lambda: fast._serve_plan(plan, un, plan.in_tree),
                    reps=micro_reps)
            finally:
                del fast._dispatch_plan       # restore the class method
            containment_frac = max(t_serve - t_inner, 0.0) / t_plan
        else:
            containment_frac = float("nan")
        # floored at 1us: the python wrapper cannot cost less, and timer
        # noise can push the subtraction (slightly) negative
        ov_plan = max(t_plan - t_kernel, 1e-6)
        ov_interp = max(t_interp - t_kernel, 1e-6)
        prob = {
            "t_jit_s": t_jit,
            "t_interpreter_s": t_interp,
            "t_plan_s": t_plan,
            "t_kernel_s": t_kernel,
            "overhead_plan_s": ov_plan,
            "overhead_interpreter_s": ov_interp,
            "dispatch_overhead_reduction": ov_interp / ov_plan,
            "interpreter_vs_plan": t_interp / t_plan,
            "plan_vs_jit": t_plan / t_jit,
            "baked": info["baked"] == 1 and not info["bake_errors"],
            "selected": [n for _, n in fast.last_selections],
            "containment_overhead_frac": containment_frac,
        }
        report["problems"][name] = prob
        emit(f"dispatch.{name}", t_plan,
             f"jit={t_jit * 1e6:.1f}us interp={t_interp * 1e6:.1f}us "
             f"plan={t_plan * 1e6:.1f}us kernel={t_kernel * 1e6:.1f}us "
             f"interp/plan={prob['interpreter_vs_plan']:.2f}x "
             f"plan/jit={prob['plan_vs_jit']:.2f}x "
             f"overhead_cut={prob['dispatch_overhead_reduction']:.0f}x")

    if seed_only:
        return report

    probs = report["problems"].values()
    report["all_baked"] = all(p["baked"] for p in probs)
    report["plan_dispatch_faster_than_interpreter"] = all(
        p["interpreter_vs_plan"] > 1.0 for p in probs)
    report["plan_speedup_over_interpreter_min"] = min(
        p["interpreter_vs_plan"] for p in probs)
    report["dispatch_overhead_reduction_min"] = min(
        p["dispatch_overhead_reduction"] for p in probs)
    report["dispatch_overhead_reduction_5x_everywhere"] = all(
        p["dispatch_overhead_reduction"] >= 5.0 for p in probs)
    report["plan_vs_jit_max"] = max(p["plan_vs_jit"] for p in probs)
    report["plan_within_1_3x_of_jit"] = report["plan_vs_jit_max"] <= 1.3

    # containment gate (shadow rate 0): the resilience layer's steady-state
    # cost must stay within 2% of plan-dispatch time on every problem.  A
    # committed prior BENCH_dispatch.json from the same host/platform is
    # additionally compared (informational — absolute times across runner
    # generations are not a stable gate).
    import math as _math
    fracs = [p["containment_overhead_frac"] for p in probs
             if not _math.isnan(p["containment_overhead_frac"])]
    report["containment_overhead_frac_max"] = max(fracs) if fracs else None
    report["containment_overhead_leq_2pct"] = bool(
        fracs and all(f <= 0.02 for f in fracs))
    report["containment_shadow_rate"] = 0.0
    baseline_cmp = {"comparable": False, "note": "no prior baseline"}
    if out:
        import json as _json
        import os as _os
        if _os.path.exists(out):
            try:
                base = _json.load(open(out, encoding="utf-8"))
            except (OSError, ValueError):
                base = None
            if base and base.get("host") == report["host"] \
                    and base.get("platform") == report["platform"]:
                ratios = {
                    n: report["problems"][n]["t_plan_s"]
                    / base["problems"][n]["t_plan_s"]
                    for n in report["problems"]
                    if n in base.get("problems", {})}
                baseline_cmp = {"comparable": bool(ratios),
                                "t_plan_vs_baseline": ratios,
                                "note": "prior report, same host/platform"}
            elif base:
                baseline_cmp = {
                    "comparable": False,
                    "note": "baseline host/platform mismatch; direct "
                            "overhead measurement gates instead"}
    report["containment_baseline"] = baseline_cmp
    emit("dispatch.containment", 0.0,
         f"overhead_frac_max={report['containment_overhead_frac_max']} "
         f"leq_2pct={report['containment_overhead_leq_2pct']}")

    # Warm start: a FRESH LilacFunction over the last problem's program
    # must rehydrate detection + pins from the persistent plan cache (the
    # compiles above seeded it) and bake without a single detector call.
    # The process-wide shared in-memory cache view is dropped first, so
    # this genuinely exercises the ON-DISK record — the same read a
    # second process (or the next CI job) performs — rather than the
    # in-memory entries this very run created.
    from repro.core import plan as plan_mod

    plan_mod.reset_shared_plan_caches()
    naive, a = last
    calls, restore = _spy_detect()
    try:
        fresh = lilac.compile(naive, mode="host", policy=policy)
        fresh(*a)
    finally:
        restore()
    pstats = fresh.plan_info()["plan_cache_stats"] or {}
    report["warm_start"] = {
        "detect_calls": calls["n"],
        "baked": fresh.plan_info()["baked"] == 1,
        "selected": [n for _, n in fresh.last_selections],
        "plan_cache_disk_hits": pstats.get("disk_hits", 0),
        "plan_cache_save_errors": pstats.get("save_errors", 0),
    }
    emit("dispatch.warm_start", 0.0,
         f"detect_calls={calls['n']} baked={report['warm_start']['baked']} "
         f"disk_hits={report['warm_start']['plan_cache_disk_hits']}")
    if out:
        write_json_report(out, report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke grid: small problems")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--policy", default="default",
                    help="harness policy for the compiled path "
                         "(default | autotune | explicit name)")
    ap.add_argument("--seed-only", action="store_true",
                    help="one resolving call per problem to populate the "
                         "persistent caches; no timing, no report")
    ap.add_argument("--out", default="BENCH_dispatch.json",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    reps = args.reps if args.reps is not None else (30 if args.quick else 100)
    run(reps=reps, quick=args.quick, out=args.out or None,
        policy=args.policy, seed_only=args.seed_only)


if __name__ == "__main__":
    main()
