"""Serving-tier benchmark: continuous vs static batching on baked plans.

Three measurements over a smoke-sized causal LM (CPU-honest; the point is
scheduler + dispatch behavior, not kernel FLOPs):

1. **continuous vs static batching** — the same deterministic closed-burst
   workload (mixed output lengths, all arrivals at t=0) through two
   engines that differ only in scheduler mode.  Static batching admits a
   batch and runs it to completion, so later requests queue behind the
   current batch's longest member; continuous batching refills each slot
   the step it frees.  Gate: ``continuous_batching_beats_static`` —
   continuous p99 end-to-end time-per-token < static p99.

2. **prewarm zero-detect** — drop the in-memory plan-cache view
   (``reset_shared_plan_caches``), spy on ``Detector.detect``, then build
   a FRESH engine and serve a first request.  The bucket-grid plans must
   rehydrate from the persistent on-disk plan cache (seeded by the
   engines of measurement 1 — or, in CI, by a previous job sharing
   ``.lilac-cache/``): gate ``prewarmed_decode_zero_detect`` — zero
   detector calls through prewarm AND the first served request.

3. **ragged vs padded MoE batch packing** — group-by-expert ragged
   packing feeding the ``moe_gmm`` kernel once with ``sum(T_i)`` tokens,
   vs the per-request-padded rectangle; records the padding-waste
   fraction and the timing ratio (recorded, not gated — interpret-mode
   kernel timings off-TPU are not meaningful thresholds).

CLI:
    python benchmarks/serving.py [--quick] [--arch NAME]
                                 [--n-requests N] [--out PATH]
"""
from __future__ import annotations

import argparse
import platform as _platform

import jax
import numpy as np

from benchmarks.common import emit, percentiles, timeit, write_json_report
from benchmarks.dispatch_overhead import _spy_detect
from repro.configs.base import get_arch, smoke_config
from repro.models.factory import build_model
from repro.serve import (BucketPolicy, Engine, Request, ServeConfig,
                         SyntheticWorkload, moe_ffn_padded, moe_ffn_ragged,
                         padding_waste)


def _quick_policy() -> BucketPolicy:
    return BucketPolicy(batch=(1, 2, 4), seq=(32, 64))


def _full_policy() -> BucketPolicy:
    return BucketPolicy(batch=(1, 2, 4, 8), seq=(64, 128, 256))


def _run_mode(model, params, policy, workload, mode: str) -> dict:
    # admit_deadline_s routes admission through Scheduler.try_admit
    # (bounded retry-with-backoff) instead of hard-rejecting on a full
    # queue; the resilience counters land in the report below
    eng = Engine(model, params,
                 ServeConfig(buckets=policy, mode=mode,
                             prefill_lengths=workload.prompt_grid,
                             admit_deadline_s=0.05))
    pairs = workload.requests()
    reqs = [r for _, r in pairs]
    snap = eng.run(pairs)
    tpt = [r.time_per_token() for r in reqs
           if r.time_per_token() is not None]
    return {
        "time_per_token_s": percentiles(tpt),
        "ttft_s": snap["ttft_s"],
        "decode_step_s": {k: snap["decode_step_s"][k]
                          for k in ("p50", "p90", "p99", "mean")},
        "steps": snap["steps"],
        "batch_occupancy": snap["batch_occupancy"],
        "bucket_hits": snap["buckets"]["hits"],
        "bucket_misses": snap["buckets"]["misses"],
        "cache_resizes": snap["buckets"]["cache_resizes"],
        "finished": snap["requests"]["finished"],
        "resilience": snap["resilience"],
    }


def _measure_packing(quick: bool) -> dict:
    rng = np.random.default_rng(0)
    E, D, F, K = 8, 64, 128, 2
    lengths = [5, 17, 9, 30] if quick else [33, 110, 57, 190, 18, 242]
    xs = [rng.standard_normal((t, D)).astype(np.float32) for t in lengths]
    gates = [rng.random((t, K)).astype(np.float32) for t in lengths]
    idxs = [rng.integers(0, E, (t, K)).astype(np.int32) for t in lengths]
    wg = rng.standard_normal((E, D, F)).astype(np.float32) * 0.05
    wu = rng.standard_normal((E, D, F)).astype(np.float32) * 0.05
    wd = rng.standard_normal((E, F, D)).astype(np.float32) * 0.05

    ragged = moe_ffn_ragged(xs, gates, idxs, wg, wu, wd, backend="gmm")
    padded = moe_ffn_padded(xs, gates, idxs, wg, wu, wd)
    matches = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
        for a, b in zip(ragged, padded))
    reps = 5 if quick else 20
    t_ragged = timeit(
        lambda: moe_ffn_ragged(xs, gates, idxs, wg, wu, wd, backend="gmm"),
        reps=reps, warmup=1)
    t_padded = timeit(
        lambda: moe_ffn_padded(xs, gates, idxs, wg, wu, wd),
        reps=reps, warmup=1)
    return {
        "lengths": lengths,
        "padding_waste": padding_waste(lengths),
        "packed_matches_padded": bool(matches),
        "t_ragged_s": t_ragged,
        "t_padded_s": t_padded,
        "padded_vs_ragged": t_padded / t_ragged,
    }


def run(quick: bool = False, arch: str = "olmoe-1b-7b",
        n_requests: int | None = None, out: str | None = None) -> dict:
    from repro import lilac

    policy = _quick_policy() if quick else _full_policy()
    n = n_requests or (12 if quick else 48)
    cfg = smoke_config(get_arch(arch)).replace(moe_decode_impl="naive_flat")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_new = (4, 24) if quick else (8, 48)
    # a small prompt-length grid: every prefill shape is prewarmed, so the
    # serving measurement is pure scheduling + dispatch, no XLA compiles
    grid = (4, 8, 12, 16) if quick else (8, 16, 32, 48)
    workload = SyntheticWorkload(n_requests=n, vocab=cfg.vocab,
                                 prompt_grid=grid, new_tokens=max_new,
                                 rate_rps=0.0, seed=0)
    report = {
        "benchmark": "serving",
        "quick": quick,
        "arch": arch,
        "platform": jax.default_backend(),
        "host": _platform.machine(),
        "buckets": policy.spec(),
        "n_requests": n,
        "plan_cache": str(lilac.default_plan_cache_path()),
    }

    # 1. continuous vs static on the identical closed burst ---------------
    cont = _run_mode(model, params, policy, workload, "continuous")
    stat = _run_mode(model, params, policy, workload, "static")
    report["continuous"] = cont
    report["static"] = stat
    report["continuous_batching_beats_static"] = (
        cont["time_per_token_s"]["p99"] < stat["time_per_token_s"]["p99"])
    report["static_vs_continuous_p99"] = (
        stat["time_per_token_s"]["p99"] / cont["time_per_token_s"]["p99"])
    emit("serving.continuous", cont["time_per_token_s"]["p99"],
         f"p50={cont['time_per_token_s']['p50'] * 1e3:.2f}ms "
         f"occupancy={cont['batch_occupancy']:.2f}")
    emit("serving.static", stat["time_per_token_s"]["p99"],
         f"p50={stat['time_per_token_s']['p50'] * 1e3:.2f}ms "
         f"occupancy={stat['batch_occupancy']:.2f}")
    emit("serving.continuous_beats_static", 0.0,
         f"{report['continuous_batching_beats_static']} "
         f"(static/continuous p99 = "
         f"{report['static_vs_continuous_p99']:.2f}x)")

    # 2. prewarmed replica: zero detection on the request path ------------
    from repro.core import plan as plan_mod
    plan_mod.reset_shared_plan_caches()
    calls, restore = _spy_detect()
    try:
        fresh = Engine(model, params,
                       ServeConfig(buckets=policy, mode="continuous",
                                   prefill_lengths=(8,)))
        prewarm_calls = calls["n"]
        probe = Request(prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=4)
        assert fresh.submit(probe)
        fresh.run_until_idle()
        serve_calls = calls["n"] - prewarm_calls
    finally:
        restore()
    pw = fresh.metrics.prewarm
    report["warm_start"] = {
        "grid": len(policy.grid()),
        "baked": pw.get("baked"),
        "plan_cache_hits": pw.get("plan_cache_hits"),
        "prewarm_detect_calls": prewarm_calls,
        "first_request_detect_calls": serve_calls,
        "first_request_tokens": list(probe.tokens),
    }
    report["prewarmed_decode_zero_detect"] = (
        prewarm_calls == 0 and serve_calls == 0
        and pw.get("baked") == len(policy.grid()))
    emit("serving.warm_start", 0.0,
         f"prewarm_detect={prewarm_calls} serve_detect={serve_calls} "
         f"baked={pw.get('baked')}/{len(policy.grid())} "
         f"zero_detect={report['prewarmed_decode_zero_detect']}")

    # 3. ragged vs padded MoE packing -------------------------------------
    report["packing"] = _measure_packing(quick)
    emit("serving.packing", report["packing"]["t_ragged_s"],
         f"waste={report['packing']['padding_waste']:.2f} "
         f"padded/ragged={report['packing']['padded_vs_ragged']:.2f}x "
         f"match={report['packing']['packed_matches_padded']}")

    if out:
        write_json_report(out, report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small grid, few requests")
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    run(quick=args.quick, arch=args.arch, n_requests=args.n_requests,
        out=args.out or None)


if __name__ == "__main__":
    main()
