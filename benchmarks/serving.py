"""Serving-tier benchmark: continuous vs static batching on baked plans.

Five measurements over a smoke-sized causal LM (CPU-honest; the point is
scheduler + dispatch behavior, not kernel FLOPs):

1. **continuous vs static batching** — the same deterministic closed-burst
   workload (mixed output lengths, all arrivals at t=0) through two
   engines that differ only in scheduler mode.  Static batching admits a
   batch and runs it to completion, so later requests queue behind the
   current batch's longest member; continuous batching refills each slot
   the step it frees.  Gate: ``continuous_batching_beats_static`` —
   continuous p99 end-to-end time-per-token < static p99.

2. **prewarm zero-detect** — drop the in-memory plan-cache view
   (``reset_shared_plan_caches``), spy on ``Detector.detect``, then build
   a FRESH engine and serve a first request.  The bucket-grid plans must
   rehydrate from the persistent on-disk plan cache (seeded by the
   engines of measurement 1 — or, in CI, by a previous job sharing
   ``.lilac-cache/``): gate ``prewarmed_decode_zero_detect`` — zero
   detector calls through prewarm AND the first served request.

3. **ragged vs padded MoE batch packing** — group-by-expert ragged
   packing feeding the ``moe_gmm`` kernel once with ``sum(T_i)`` tokens,
   vs the per-request-padded rectangle; records the padding-waste
   fraction and the timing ratio (recorded, not gated — interpret-mode
   kernel timings off-TPU are not meaningful thresholds).

4. **Poisson saturation curve** — a 2-replica front door driven at
   increasing ``SyntheticWorkload(rate_rps=...)`` offered loads; records
   achieved throughput, TTFT and time-per-token percentiles per rate
   (recorded, not gated — CPU-host absolute latencies are not
   thresholds).

5. **front-door chaos** — 3 replicas under Poisson load with
   ``decode_raise`` + ``decode_nan`` firing; one replica is killed
   mid-burst with the ``replica_crash`` fault kind.  Gates:
   ``all_requests_accounted_for`` (every submitted request finished or
   failed with an attributed reason — zero silent drops),
   ``failover_zero_uncontained`` (exactly the injected failure, nothing
   escaped the front door), ``survivors_bit_identical_to_solo``
   (finished streams replay exactly on a solo engine), and — from a
   forced ``shadow_diverge`` incident — ``shadow_rate_spikes_and_decays``
   (the request-shadow rate spikes >= 8x its floor, then decays below 2x
   within the clean-streak window).

CLI:
    python benchmarks/serving.py [--quick] [--arch NAME]
                                 [--n-requests N] [--out PATH]
"""
from __future__ import annotations

import argparse
import contextlib
import math
import os
import platform as _platform
import tempfile
import time
from collections import deque

import jax
import numpy as np

from benchmarks.common import emit, percentiles, timeit, write_json_report
from benchmarks.dispatch_overhead import _spy_detect
from repro.configs.base import get_arch, smoke_config
from repro.models.factory import build_model
from repro.serve import (BucketPolicy, Engine, FrontDoor, Request,
                         ServeConfig, SyntheticWorkload, moe_ffn_padded,
                         moe_ffn_ragged, padding_waste)


def _quick_policy() -> BucketPolicy:
    return BucketPolicy(batch=(1, 2, 4), seq=(32, 64))


def _full_policy() -> BucketPolicy:
    return BucketPolicy(batch=(1, 2, 4, 8), seq=(64, 128, 256))


def _run_mode(model, params, policy, workload, mode: str) -> dict:
    # admit_deadline_s routes admission through Scheduler.try_admit
    # (bounded retry-with-backoff) instead of hard-rejecting on a full
    # queue; the resilience counters land in the report below
    eng = Engine(model, params,
                 ServeConfig(buckets=policy, mode=mode,
                             prefill_lengths=workload.prompt_grid,
                             admit_deadline_s=0.05))
    pairs = workload.requests()
    reqs = [r for _, r in pairs]
    snap = eng.run(pairs)
    tpt = [r.time_per_token() for r in reqs
           if r.time_per_token() is not None]
    return {
        "time_per_token_s": percentiles(tpt),
        "ttft_s": snap["ttft_s"],
        "decode_step_s": {k: snap["decode_step_s"][k]
                          for k in ("p50", "p90", "p99", "mean")},
        "steps": snap["steps"],
        "batch_occupancy": snap["batch_occupancy"],
        "bucket_hits": snap["buckets"]["hits"],
        "bucket_misses": snap["buckets"]["misses"],
        "cache_resizes": snap["buckets"]["cache_resizes"],
        "finished": snap["requests"]["finished"],
        "resilience": snap["resilience"],
    }


def _measure_packing(quick: bool) -> dict:
    rng = np.random.default_rng(0)
    E, D, F, K = 8, 64, 128, 2
    lengths = [5, 17, 9, 30] if quick else [33, 110, 57, 190, 18, 242]
    xs = [rng.standard_normal((t, D)).astype(np.float32) for t in lengths]
    gates = [rng.random((t, K)).astype(np.float32) for t in lengths]
    idxs = [rng.integers(0, E, (t, K)).astype(np.int32) for t in lengths]
    wg = rng.standard_normal((E, D, F)).astype(np.float32) * 0.05
    wu = rng.standard_normal((E, D, F)).astype(np.float32) * 0.05
    wd = rng.standard_normal((E, F, D)).astype(np.float32) * 0.05

    ragged = moe_ffn_ragged(xs, gates, idxs, wg, wu, wd, backend="gmm")
    padded = moe_ffn_padded(xs, gates, idxs, wg, wu, wd)
    matches = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
        for a, b in zip(ragged, padded))
    reps = 5 if quick else 20
    t_ragged = timeit(
        lambda: moe_ffn_ragged(xs, gates, idxs, wg, wu, wd, backend="gmm"),
        reps=reps, warmup=1)
    t_padded = timeit(
        lambda: moe_ffn_padded(xs, gates, idxs, wg, wu, wd),
        reps=reps, warmup=1)
    return {
        "lengths": lengths,
        "padding_waste": padding_waste(lengths),
        "packed_matches_padded": bool(matches),
        "t_ragged_s": t_ragged,
        "t_padded_s": t_padded,
        "padded_vs_ragged": t_padded / t_ragged,
    }


@contextlib.contextmanager
def _scratch_quarantine():
    """Redirect the shared quarantine store to a throwaway file: the
    chaos measurements deliberately quarantine healthy kernels (forced
    divergence), which must not poison the ambient store other CI steps
    and later benchmarks read."""
    from repro.core import resilience as RES
    prev = os.environ.get("LILAC_QUARANTINE_CACHE")
    with tempfile.TemporaryDirectory(prefix="lilac-chaos-q-") as d:
        os.environ["LILAC_QUARANTINE_CACHE"] = os.path.join(
            d, "quarantine.json")
        RES.reset_shared_quarantine()
        try:
            yield
        finally:
            if prev is None:
                os.environ.pop("LILAC_QUARANTINE_CACHE", None)
            else:
                os.environ["LILAC_QUARANTINE_CACHE"] = prev
            RES.reset_shared_quarantine()


def _drive(fd: FrontDoor, pairs, *, on_step=None, max_steps=200_000):
    """FrontDoor.run with a per-step hook (the chaos measurement uses it
    to fire the mid-burst crash and to poll the shadow controller)."""
    pending = deque(sorted(pairs, key=lambda ar: ar[0]))
    start = time.perf_counter()
    steps = 0
    while pending or not fd.idle:
        now = time.perf_counter() - start
        while pending and pending[0][0] <= now:
            _, req = pending.popleft()
            fd.submit(req)
        if fd.idle:
            if pending:
                wait = pending[0][0] - (time.perf_counter() - start)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
            continue
        fd.step()
        steps += 1
        if on_step is not None:
            on_step(steps)
        if steps > max_steps:
            raise RuntimeError(f"fleet did not drain in {max_steps} steps")
    return time.perf_counter() - start


def _measure_saturation(model, params, policy, quick, vocab, grid,
                        max_new) -> dict:
    rates = (60.0, 240.0) if quick else (30.0, 120.0, 480.0)
    n = 8 if quick else 24
    cfg = ServeConfig(buckets=policy, prefill_lengths=grid,
                      admit_deadline_s=0.05)
    points = []
    for rate in rates:
        fd = FrontDoor([Engine(model, params, cfg) for _ in range(2)])
        wl = SyntheticWorkload(n_requests=n, vocab=vocab, prompt_grid=grid,
                               new_tokens=max_new, rate_rps=rate, seed=3)
        pairs = wl.requests()
        reqs = [r for _, r in pairs]
        wall = _drive(fd, pairs)
        snap = fd.snapshot()
        tpt = [r.time_per_token() for r in reqs
               if r.time_per_token() is not None]
        ttft = [r.ttft_s for r in reqs if r.ttft_s is not None]
        toks = snap["fleet"]["tokens_generated"]
        points.append({
            "offered_rps": rate,
            "achieved_rps": (snap["fleet"]["finished"] / wall
                             if wall > 0 else float("nan")),
            "tokens_per_s": toks / wall if wall > 0 else float("nan"),
            "ttft_s": percentiles(ttft),
            "time_per_token_s": percentiles(tpt),
            "finished": snap["fleet"]["finished"],
            "rejected": snap["fleet"]["rejected"],
            "accounted": snap["fleet"]["all_requests_accounted_for"],
        })
        emit("serving.saturation", points[-1]["tokens_per_s"],
             f"offered={rate:g}rps ttft_p99="
             f"{points[-1]['ttft_s']['p99'] * 1e3:.1f}ms")
    return {"n_requests": n, "replicas": 2, "points": points,
            "all_rates_accounted": all(p["accounted"] for p in points)}


def _measure_frontdoor_chaos(model, params, policy, quick, vocab, grid,
                             max_new, seed: int = 0) -> dict:
    """3-replica fleet under Poisson load + decode faults; replica 1 is
    killed mid-burst via replica_crash.  See module docstring, item 5."""
    from repro.core import faults
    n = 12 if quick else 30
    cfg = ServeConfig(buckets=policy, prefill_lengths=grid,
                      admit_deadline_s=0.05, request_shadow_rate=0.25)
    engines = [Engine(model, params, cfg) for _ in range(3)]
    fd = FrontDoor(engines)
    wl = SyntheticWorkload(n_requests=n, vocab=vocab, prompt_grid=grid,
                           new_tokens=max_new,
                           rate_rps=200.0 if quick else 120.0, seed=seed)
    pairs = wl.requests()
    reqs = [r for _, r in pairs]
    crashed = [False]
    fired_kinds = set()

    def on_step(_steps):
        # mid-burst: once a third of the work is done (and more is still
        # arriving / in flight), kill replica 1
        if not crashed[0] \
                and sum(r.done for r in reqs) * 3 >= n:
            crashed[0] = True
            with faults.inject("replica_crash:replica1",
                               seed=seed) as crash_plan:
                fd.step()
            fired_kinds.update(k for k, _, _ in crash_plan.fired)

    with faults.inject("decode_raise:decode:0.04,decode_nan:decode:0.04",
                       seed=seed) as plan:
        _drive(fd, pairs, on_step=on_step)
    fired_kinds.update(k for k, _, _ in plan.fired)

    # verification happens OUTSIDE any fault context.  Every finished
    # stream must replay bit-identically solo: all of them via the
    # survivor's replay_solo (same prewarmed plans, no rebuild), plus a
    # small sample through a fully fresh generate_solo engine.
    survivor = fd.healthy_replicas()[0].engine
    finished = [r for r in reqs if r.done and r.failed is None]
    mismatches = 0
    mismatch_detail = []

    def _record_mismatch(r, solo, how):
        div = next((i for i, (a, b) in enumerate(zip(solo, r.tokens))
                    if a != b), min(len(solo), len(r.tokens)))
        mismatch_detail.append({
            "how": how, "rid": r.rid,
            "replica": fd.assignment.get(r.rid),
            "prompt_len": r.prompt_len, "n_tokens": len(r.tokens),
            "first_divergence": div,
            "served": [int(t) for t in r.tokens],
            "solo": [int(t) for t in solo],
        })

    for r in finished:
        solo = survivor.replay_solo(r)
        if solo != list(r.tokens):
            mismatches += 1
            _record_mismatch(r, solo, "replay_solo")
    for r in finished[:3]:
        solo = survivor.generate_solo(r.prompt, r.max_new_tokens,
                                      eos_id=r.eos_id)
        if solo != list(r.tokens):
            mismatches += 1
            _record_mismatch(r, solo, "generate_solo")
    snap = fd.snapshot()
    out = {
        "n_requests": n,
        "injected_kinds": sorted(fired_kinds),
        "crash_fired": crashed[0] and "replica_crash" in fired_kinds,
        "failovers": fd.failovers,
        "redistributed": fd.redistributed,
        "replica_lost": fd.lost,
        "healthy_after": len(fd.healthy_replicas()),
        "finished": len(finished),
        "failed_reasons": snap["fleet"]["failed_reasons"],
        "decode_faults": snap["resilience"]["decode_faults"],
        "request_shadow_checks":
            snap["resilience"]["request_shadow_checks"],
        "request_shadow_divergences":
            snap["resilience"]["request_shadow_divergences"],
        "solo_mismatches": mismatches,
        "mismatch_detail": mismatch_detail,
        "all_requests_accounted_for": fd.accounted(),
        "survivors_bit_identical_to_solo": (len(finished) > 0
                                            and mismatches == 0),
        "failover_zero_uncontained": (crashed[0]
                                      and "replica_crash" in fired_kinds
                                      and fd.failovers == 1
                                      and len(fd.healthy_replicas()) == 2),
    }
    emit("serving.chaos", float(len(finished)),
         f"failovers={fd.failovers} redistributed={fd.redistributed} "
         f"lost={fd.lost} accounted={out['all_requests_accounted_for']} "
         f"solo_mismatch={mismatches}")
    return out


def _measure_adaptive_shadow(model, params, policy, quick, vocab, grid,
                             seed: int = 0) -> dict:
    """Forced shadow_diverge incident on a served request: the effective
    request-shadow rate must spike >= 8x its floor, then decay below 2x
    within the clean-streak window (ceil(log(spike/2)/log(1/decay)) + 1
    clean checks)."""
    from repro.core import faults
    from repro.core import resilience as RES
    cfg = ServeConfig(buckets=policy, prefill_lengths=grid,
                      request_shadow_rate=1.0)
    eng = Engine(model, params, cfg)
    fd = FrontDoor([eng])
    shadow = eng._request_shadow

    def _wl(n, s):
        return SyntheticWorkload(n_requests=n, vocab=vocab,
                                 prompt_grid=grid, new_tokens=(3, 6),
                                 rate_rps=0.0, seed=s).requests()

    # one diverged request is enough: inject over a single-request burst
    with faults.inject("shadow_diverge:request", seed=seed):
        _drive(fd, _wl(1, seed + 100))
    peak = shadow.peak_multiplier
    checks_at_spike = shadow.checks
    window = math.ceil(math.log(max(RES.shadow_spike() / 2.0, 1.0))
                       / math.log(1.0 / RES.shadow_decay())) + 1
    # clean traffic decays the spike; count the checks it takes
    checks_to_recover = None
    for burst in range(4):
        _drive(fd, _wl(window, seed + 200 + burst))
        if shadow.multiplier < 2.0:
            checks_to_recover = shadow.checks - checks_at_spike
            break
    snap = shadow.snapshot()
    out = {
        "floor": snap["floor"],
        "spike": snap["spike"],
        "decay": snap["decay"],
        "peak_multiplier": peak,
        "final_multiplier": snap["multiplier"],
        "incidents": snap["incidents"],
        "clean_window": window,
        "checks_to_recover": checks_to_recover,
        "shadow_rate_spikes_and_decays": (
            peak >= 8.0
            and snap["multiplier"] < 2.0
            and checks_to_recover is not None
            and checks_to_recover <= window),
    }
    emit("serving.adaptive_shadow", peak,
         f"peak={peak:g}x decay_in={checks_to_recover} "
         f"(window={window}) ok={out['shadow_rate_spikes_and_decays']}")
    return out


def run(quick: bool = False, arch: str = "olmoe-1b-7b",
        n_requests: int | None = None, out: str | None = None) -> dict:
    from repro import lilac

    policy = _quick_policy() if quick else _full_policy()
    n = n_requests or (12 if quick else 48)
    cfg = smoke_config(get_arch(arch)).replace(moe_decode_impl="naive_flat")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_new = (4, 24) if quick else (8, 48)
    # a small prompt-length grid: every prefill shape is prewarmed, so the
    # serving measurement is pure scheduling + dispatch, no XLA compiles
    grid = (4, 8, 12, 16) if quick else (8, 16, 32, 48)
    workload = SyntheticWorkload(n_requests=n, vocab=cfg.vocab,
                                 prompt_grid=grid, new_tokens=max_new,
                                 rate_rps=0.0, seed=0)
    report = {
        "benchmark": "serving",
        "quick": quick,
        "arch": arch,
        "platform": jax.default_backend(),
        "host": _platform.machine(),
        "buckets": policy.spec(),
        "n_requests": n,
        "plan_cache": str(lilac.default_plan_cache_path()),
    }

    # 1. continuous vs static on the identical closed burst ---------------
    cont = _run_mode(model, params, policy, workload, "continuous")
    stat = _run_mode(model, params, policy, workload, "static")
    report["continuous"] = cont
    report["static"] = stat
    report["continuous_batching_beats_static"] = (
        cont["time_per_token_s"]["p99"] < stat["time_per_token_s"]["p99"])
    report["static_vs_continuous_p99"] = (
        stat["time_per_token_s"]["p99"] / cont["time_per_token_s"]["p99"])
    emit("serving.continuous", cont["time_per_token_s"]["p99"],
         f"p50={cont['time_per_token_s']['p50'] * 1e3:.2f}ms "
         f"occupancy={cont['batch_occupancy']:.2f}")
    emit("serving.static", stat["time_per_token_s"]["p99"],
         f"p50={stat['time_per_token_s']['p50'] * 1e3:.2f}ms "
         f"occupancy={stat['batch_occupancy']:.2f}")
    emit("serving.continuous_beats_static", 0.0,
         f"{report['continuous_batching_beats_static']} "
         f"(static/continuous p99 = "
         f"{report['static_vs_continuous_p99']:.2f}x)")

    # 2. prewarmed replica: zero detection on the request path ------------
    from repro.core import plan as plan_mod
    plan_mod.reset_shared_plan_caches()
    calls, restore = _spy_detect()
    try:
        fresh = Engine(model, params,
                       ServeConfig(buckets=policy, mode="continuous",
                                   prefill_lengths=(8,)))
        prewarm_calls = calls["n"]
        probe = Request(prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=4)
        assert fresh.submit(probe)
        fresh.run_until_idle()
        serve_calls = calls["n"] - prewarm_calls
    finally:
        restore()
    pw = fresh.metrics.prewarm
    report["warm_start"] = {
        "grid": len(policy.grid()),
        "baked": pw.get("baked"),
        "plan_cache_hits": pw.get("plan_cache_hits"),
        "prewarm_detect_calls": prewarm_calls,
        "first_request_detect_calls": serve_calls,
        "first_request_tokens": list(probe.tokens),
    }
    report["prewarmed_decode_zero_detect"] = (
        prewarm_calls == 0 and serve_calls == 0
        and pw.get("baked") == len(policy.grid()))
    emit("serving.warm_start", 0.0,
         f"prewarm_detect={prewarm_calls} serve_detect={serve_calls} "
         f"baked={pw.get('baked')}/{len(policy.grid())} "
         f"zero_detect={report['prewarmed_decode_zero_detect']}")

    # 3. ragged vs padded MoE packing -------------------------------------
    report["packing"] = _measure_packing(quick)
    emit("serving.packing", report["packing"]["t_ragged_s"],
         f"waste={report['packing']['padding_waste']:.2f} "
         f"padded/ragged={report['packing']['padded_vs_ragged']:.2f}x "
         f"match={report['packing']['packed_matches_padded']}")

    # 4. Poisson saturation curve through the front door ------------------
    report["saturation"] = _measure_saturation(
        model, params, policy, quick, cfg.vocab, grid, max_new)

    # 5. front-door chaos + adaptive shadow (scratch quarantine: forced
    # divergence must not poison the ambient incident store) --------------
    with _scratch_quarantine():
        report["frontdoor_chaos"] = _measure_frontdoor_chaos(
            model, params, policy, quick, cfg.vocab, grid, max_new)
        report["adaptive_shadow"] = _measure_adaptive_shadow(
            model, params, policy, quick, cfg.vocab, grid)
    report["all_requests_accounted_for"] = \
        report["frontdoor_chaos"]["all_requests_accounted_for"]
    report["failover_zero_uncontained"] = \
        report["frontdoor_chaos"]["failover_zero_uncontained"]
    report["survivors_bit_identical_to_solo"] = \
        report["frontdoor_chaos"]["survivors_bit_identical_to_solo"]
    report["shadow_rate_spikes_and_decays"] = \
        report["adaptive_shadow"]["shadow_rate_spikes_and_decays"]

    if out:
        write_json_report(out, report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small grid, few requests")
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    run(quick=args.quick, arch=args.arch, n_requests=args.n_requests,
        out=args.out or None)


if __name__ == "__main__":
    main()
