"""Paper Table 2 / Fig. 17: per-backend speedups across applications and
inputs — demonstrating that no backend wins everywhere (the reason the
harness registry supports per-platform selection and autotuning).

This sweep doubles as the autotuner's external measurement pass: the
steady-state timings it collects — kernel AND measured conversion-path
(marshal) seconds — are recorded into the persistent autotune cache
(``repro.core.autotune``), so a later ``lilac.compile(fn, mode="host",
policy="autotune")`` in ANY process warm-starts from the sweep instead of
re-timing.  The JSON report compares the tuned selection against the
static per-platform default on every (problem, context) cell; because the
tuned pick is the argmin of the same measurements, it is never slower than
the default in the report — the Table 2 "always pick the right backend"
win.  It also compares marshal-aware tuning (winner = argmin of kernel +
repack/reuse, the steady-state amortized cost) against the kernel-only
argmin: at the declared call frequency the marshal-aware pick's end-to-end
cost is never worse.

CLI:
    python benchmarks/tab2_backends.py [--quick] [--reps N] [--out PATH]

``--quick`` runs the small CI smoke grid and is what the bench-smoke CI job
executes; ``--out`` (default BENCH_tab2_backends.json) is uploaded as the
perf-trajectory artifact.
"""
from __future__ import annotations

import argparse
import platform as _platform

import jax

from benchmarks.common import (emit, naive_spmv_fn, problem_suite, timeit,
                               vec_for, write_json_report)
from repro import lilac
from repro.core import REGISTRY, signature_of

BACKENDS = ["jnp.segment", "jnp.ell", "jnp.bcsr", "jnp.dense"]


def _default_backend(plat: str) -> str:
    return REGISTRY.default_name("spmv_csr", plat) or BACKENDS[0]


def run(reps: int = 10, quick: bool = False, out: str | None = None) -> dict:
    """Two calling contexts per (problem, backend):
    steady — matrix reused across calls (marshaling amortized; the
             PageRank/CG regime), and
    cold   — matrix changes every call (conversion on the critical path;
             the streaming regime).
    The winner flips between contexts and problems — the paper's Table 2
    conclusion (no universally-best backend) in single-platform form."""
    suite = problem_suite(quick=quick)
    plat = jax.default_backend()
    tuner = REGISTRY.autotuner
    table = {}
    best = {}
    report = {
        "benchmark": "tab2_backends",
        "quick": quick,
        "reps": reps,
        "platform": plat,
        "host": _platform.machine(),
        "backends": BACKENDS,
        "default_backend": _default_backend(plat),
        "autotune_cache": str(tuner.cache.path),
        "problems": {},
    }
    for prob_name, csr in suite.items():
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        base = jax.jit(naive)
        t_naive = timeit(base, csr.val, csr.col_ind, csr.row_ptr, vec,
                         reps=reps)
        row = {}
        abs_t = {"steady": {}, "cold": {}}
        marshal_t = {}
        tune_match = None
        for backend in BACKENDS:
            # steady and cold fail independently: a cold-path exception
            # (repack on the critical path) must not retract the backend's
            # already-measured steady result, or the report's winner and the
            # autotune-cache seed would disagree about the candidate set.
            try:
                acc = lilac.compile(naive, mode="host", policy=backend)
                t = timeit(acc, csr.val, csr.col_ind, csr.row_ptr, vec,
                           reps=reps)
                row[(backend, "steady")] = t_naive / t
                abs_t["steady"][backend] = t
                if acc.last_selections and tune_match is None:
                    # the detected Match: its binding atoms carry avals, so
                    # it keys the same autotune signature that a later
                    # policy="autotune" call will compute from live values.
                    tune_match = acc.last_selections[0][0]
                # measured conversion-path seconds for this backend's
                # marshal clauses (0.0 for repack-free backends)
                try:
                    h = REGISTRY.get(tune_match.computation
                                     if tune_match else "spmv_csr", backend)
                    marshal_t[backend] = acc.cache.estimate_marshal_seconds(
                        h.marshal)
                except Exception:
                    marshal_t[backend] = 0.0
            except Exception:
                row[(backend, "steady")] = float("nan")
                row[(backend, "cold")] = float("nan")
                continue
            try:
                def cold_call():
                    acc.cache.clear()
                    return acc(csr.val, csr.col_ind, csr.row_ptr, vec)

                t_cold = timeit(cold_call, reps=max(2, reps // 3))
                row[(backend, "cold")] = t_naive / t_cold
                abs_t["cold"][backend] = t_cold
            except Exception:
                row[(backend, "cold")] = float("nan")
        table[prob_name] = row
        prob_report = {"t_naive_s": t_naive, "contexts": {}}
        for ctx in ("steady", "cold"):
            cands = [b for b in BACKENDS if row[(b, ctx)] == row[(b, ctx)]]
            winner = max(cands, key=lambda b: row[(b, ctx)])
            best[(prob_name, ctx)] = winner
            cells = " ".join(f"{b}={row[(b, ctx)]:.2f}x" for b in cands)
            emit(f"tab2.{prob_name}.{ctx}", t_naive,
                 f"{cells} best={winner}")
            default = _default_backend(plat)
            t_default = abs_t[ctx].get(default, float("nan"))
            t_tuned = abs_t[ctx][winner]
            prob_report["contexts"][ctx] = {
                "times_s": abs_t[ctx],
                "speedups_vs_naive": {b: row[(b, ctx)] for b in cands},
                "default": default,
                "tuned": winner,
                "t_default_s": t_default,
                "t_tuned_s": t_tuned,
                "tuned_vs_default": (t_default / t_tuned
                                     if t_tuned == t_tuned else float("nan")),
                "tuned_never_slower": bool(t_tuned <= t_default)
                                      if t_default == t_default else True,
            }
        # Marshal-aware vs kernel-only tuning on the steady context: the
        # amortized cost (kernel + repack/reuse at the declared call
        # frequency) of the marshal-aware argmin is, by construction, never
        # worse than the kernel-only argmin's — surfaced per problem so the
        # acceptance gate can assert it.
        if abs_t["steady"]:
            from repro.core.autotune import Autotuner
            reuse = lilac.MarshalPolicy().reuse
            amort = Autotuner.amortized(abs_t["steady"], marshal_t, reuse)
            kernel_winner = min(abs_t["steady"], key=abs_t["steady"].get)
            marshal_winner = min(amort, key=amort.get)
            prob_report["marshal_aware"] = {
                "reuse": reuse,
                "marshal_s": marshal_t,
                "amortized_s": amort,
                "tuned_kernel_only": kernel_winner,
                "tuned_with_marshal_cost": marshal_winner,
                "never_slower": bool(
                    amort[marshal_winner] <= amort[kernel_winner]),
            }
            emit(f"tab2.{prob_name}.marshal_aware", amort[marshal_winner],
                 f"kernel_only={kernel_winner} "
                 f"with_marshal_cost={marshal_winner}")
        # Seed the persistent autotune cache from the steady-state sweep
        # (kernel + marshal measurements): this run IS the measurement, so
        # a later policy="autotune" process selects the amortized winner
        # here with zero re-timing.
        if tune_match is not None and abs_t["steady"]:
            m = tune_match
            tuned = tuner.record_external(m.computation, m.format, plat,
                                          "host", m.binding, abs_t["steady"],
                                          marshal_s=marshal_t, reuse=reuse)
            prob_report["autotune_signature"] = signature_of(
                m.computation, m.format, plat, m.binding)
            prob_report["autotune_recorded"] = tuned
        report["problems"][prob_name] = prob_report
    emit("tab2.distinct_winners", 0.0,
         f"n={len(set(best.values()))} of {len(BACKENDS)} backends win in "
         f"some (problem x context) cell")
    report["distinct_winners"] = len(set(best.values()))
    report["tuned_never_slower_everywhere"] = all(
        c["tuned_never_slower"]
        for p in report["problems"].values() for c in p["contexts"].values())
    report["tuned_with_marshal_cost_never_slower_everywhere"] = all(
        p.get("marshal_aware", {}).get("never_slower", True)
        for p in report["problems"].values())
    # End-to-end proof that the cache is live: a fresh autotune-policy pass
    # over the last problem must select from the cache without re-timing.
    timing_before = tuner.stats.timing_calls
    acc = lilac.compile(naive, mode="host", policy="autotune")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    report["warm_start"] = {
        "selected": acc.last_selections[0][1] if acc.last_selections else None,
        "re_timed_candidates": tuner.stats.timing_calls - timing_before,
    }
    if out:
        write_json_report(out, report)
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke grid: small problems, few reps")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_tab2_backends.json",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    reps = args.reps if args.reps is not None else (3 if args.quick else 10)
    run(reps=reps, quick=args.quick, out=args.out or None)


if __name__ == "__main__":
    main()
