"""Paper Table 2 / Fig. 17: per-backend speedups across applications and
inputs — demonstrating that no backend wins everywhere (the reason the
harness registry supports per-platform selection and autotuning)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, naive_spmv_fn, problem_suite, timeit, vec_for
from repro.core import lilac_accelerate

BACKENDS = ["jnp.segment", "jnp.ell", "jnp.bcsr", "jnp.dense"]


def run(reps: int = 10) -> dict:
    """Two calling contexts per (problem, backend):
    steady — matrix reused across calls (marshaling amortized; the
             PageRank/CG regime), and
    cold   — matrix changes every call (conversion on the critical path;
             the streaming regime).
    The winner flips between contexts and problems — the paper's Table 2
    conclusion (no universally-best backend) in single-platform form."""
    suite = problem_suite()
    table = {}
    best = {}
    for prob_name, csr in suite.items():
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        base = jax.jit(naive)
        t_naive = timeit(base, csr.val, csr.col_ind, csr.row_ptr, vec,
                         reps=reps)
        row = {}
        for backend in BACKENDS:
            try:
                acc = lilac_accelerate(naive, policy=backend)
                t = timeit(acc, csr.val, csr.col_ind, csr.row_ptr, vec,
                           reps=reps)
                row[(backend, "steady")] = t_naive / t

                def cold_call():
                    acc.cache.clear()
                    return acc(csr.val, csr.col_ind, csr.row_ptr, vec)

                t_cold = timeit(cold_call, reps=max(2, reps // 3))
                row[(backend, "cold")] = t_naive / t_cold
            except Exception:
                row[(backend, "steady")] = float("nan")
                row[(backend, "cold")] = float("nan")
        table[prob_name] = row
        for ctx in ("steady", "cold"):
            cands = [b for b in BACKENDS if row[(b, ctx)] == row[(b, ctx)]]
            winner = max(cands, key=lambda b: row[(b, ctx)])
            best[(prob_name, ctx)] = winner
            cells = " ".join(f"{b}={row[(b, ctx)]:.2f}x" for b in cands)
            emit(f"tab2.{prob_name}.{ctx}", t_naive,
                 f"{cells} best={winner}")
    emit("tab2.distinct_winners", 0.0,
         f"n={len(set(best.values()))} of {len(BACKENDS)} backends win in "
         f"some (problem x context) cell")
    return table


if __name__ == "__main__":
    run()
