"""Paper Table 2 / Fig. 17: per-backend speedups across applications and
inputs — demonstrating that no backend wins everywhere (the reason the
harness registry supports per-platform selection and autotuning).

This sweep doubles as the autotuner's external measurement pass: the
steady-state timings it collects — kernel AND measured conversion-path
(marshal) seconds — are recorded into the persistent autotune cache
(``repro.core.autotune``), so a later ``lilac.compile(fn, mode="host",
policy="autotune")`` in ANY process warm-starts from the sweep instead of
re-timing.  The JSON report compares the tuned selection against the
static per-platform default on every (problem, context) cell; because the
tuned pick is the argmin of the same measurements, it is never slower than
the default in the report — the Table 2 "always pick the right backend"
win.  It also compares marshal-aware tuning (winner = argmin of kernel +
repack/reuse, the steady-state amortized cost) against the kernel-only
argmin: at the declared call frequency the marshal-aware pick's end-to-end
cost is never worse.

Since schema 3 the sweep also covers *kernel schedules*: for each
tune-declaring harness it times every declared schedule variant (capped by
``--max-variants``) through one shared data plane, reports the swept-best
vs the default (fixed-constant) schedule, gates
``tuned_schedule_never_slower_than_default_schedule``, and measures the
fused-epilogue variant (spmv+bias+relu in one harness call) against the
unfused harness-then-activation realization.

Since schema 4 the ``--joint`` mode also grades the *joint whole-program
plan search* (``repro.core.plan_search``): for each problem it builds a
two-match coupled program (two spmv calls on the same matrix) at a
flip-inducing reuse rate and records ``joint_vs_greedy`` — the model-cost
ratio of the sequential per-match argmin over the beam-searched joint
assignment that shares the repack — plus an end-to-end autotuned compile
of the coupled program proving the pass manager runs the search and pins
its assignment.  Gates: ``joint_never_slower_than_greedy`` everywhere and
``joint_beats_greedy_somewhere`` (the shared-repack flip exists).

CLI:
    python benchmarks/tab2_backends.py [--quick] [--reps N] [--out PATH]
                                       [--max-variants N] [--joint]

``--quick`` runs the small CI smoke grid and is what the bench-smoke CI job
executes; ``--out`` (default BENCH_tab2_backends.json) is uploaded as the
perf-trajectory artifact.  ``--max-variants`` caps each harness's swept
schedule family so the smoke job stays inside its time budget.
"""
from __future__ import annotations

import argparse
import platform as _platform

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (emit, naive_spmv_fn, problem_suite, sweep,
                               timeit, vec_for, write_json_report)
from repro import lilac
from repro.core import REGISTRY, signature_of
from repro.core.autotune import schedule_key
from repro.core.harness import CallCtx
from repro.core.marshal import DataPlane
from repro.core.rewrite import apply_epilogue

BACKENDS = ["jnp.segment", "jnp.ell", "jnp.bcsr", "jnp.dense"]

# tune-declaring harnesses swept per problem (by explicit name: the Pallas
# backends are TPU-targeted and run the interpreter on CPU — their
# *relative* schedule ranking is still meaningful and is what the gate
# checks)
SCHEDULE_HARNESSES = ["pallas.ell"]


def _default_backend(plat: str) -> str:
    return REGISTRY.default_name("spmv_csr", plat) or BACKENDS[0]


def _csr_binding(csr, vec) -> dict:
    return {"a": csr.val, "colidx": csr.col_ind, "rowstr": csr.row_ptr,
            "iv": vec, "rows": csr.rows, "nnz": csr.nnz}


def schedule_sweep(csr, vec, harness_name: str, reps: int,
                   max_variants: int, plat: str) -> dict | None:
    """Steady-state time every schedule variant of one harness on one
    problem, through a single shared DataPlane (variants of a harness
    share its marshaled format, so the repack is paid once)."""
    try:
        h = REGISTRY.get("spmv_csr", harness_name)
    except KeyError:
        return None
    scheds = list(h.schedules) or [None]
    if max_variants > 0:
        scheds = scheds[:max_variants]
    binding = _csr_binding(csr, vec)
    ctx = CallCtx(mode="host", cache=DataPlane(), format="CSR",
                  platform=plat)

    def call(s):
        def fn():
            ctx.schedule = s
            return h(binding, ctx)
        return fn

    by_key = {schedule_key(s): s for s in scheds}
    times = sweep({k: call(s) for k, s in by_key.items()},
                  reps=reps, warmup=1)
    default_key = schedule_key(scheds[0] if scheds[0] is not None else None)
    valid = {k: t for k, t in times.items() if t == t}
    if not valid or default_key not in valid:
        return None
    best_key = min(valid, key=valid.get)
    t_default, t_best = valid[default_key], valid[best_key]

    # Drive the REAL autotuner (successive halving, isolated cache) over
    # the same family and grade the schedule it PINS against the default
    # in the exhaustive table above.  The exhaustive argmin satisfies
    # best <= default by construction; the tuner's pick does not — a sweep
    # regression (winner ignoring its measurements, stale pin) fails this
    # gate.  10% tolerance absorbs noise between the two measurement
    # passes.
    import pathlib
    import tempfile

    from repro.core.autotune import Autotuner, AutotuneCache
    tuner = Autotuner(
        registry_fingerprint="tab2-schedule-sweep",
        cache=AutotuneCache(
            pathlib.Path(tempfile.mkdtemp(prefix="tab2-autotune-"))
            / "autotune.json"),
        reps=2, max_variants=max_variants or None)
    tctx = CallCtx(mode="host", cache=ctx.cache, format="CSR",
                   platform=plat)
    sel = tuner.select("spmv_csr", "CSR", plat, "host", [h], binding, tctx,
                       default_name=harness_name)
    pinned = tuner.last_decision.schedule if sel is not None else None
    pinned_key = schedule_key(pinned)
    t_pinned = valid.get(pinned_key, float("nan"))
    gate = bool(t_pinned <= t_default * 1.10) if t_pinned == t_pinned \
        else False

    result = {
        "harness": harness_name,
        "variant_s": times,
        "n_variants": len(scheds),
        "n_variants_declared": max(len(h.schedules), 1),
        "default_schedule": default_key,
        "t_default_schedule_s": t_default,
        "best_schedule": best_key,
        "t_best_schedule_s": t_best,
        "swept_vs_default_schedule": t_default / t_best,
        "autotuner_pinned_schedule": pinned_key,
        "t_autotuner_pinned_s": t_pinned,
        "schedule_gate_tolerance": 1.10,
        "tuned_schedule_never_slower_than_default_schedule": gate,
    }

    # fused-epilogue margin, measured on the *direct ELL* entry point
    # where the epilogue truly fuses in-register (one kernel call, single
    # output store) — the unfused realization is the same kernel followed
    # by eager bias-add + relu, paying extra output round-trips.  (The
    # CSR entry point applies the epilogue post-permutation, which is
    # body-level and wouldn't isolate the fusion win.)  Both sides run the
    # problem's swept-best schedule — the configuration the autotuner
    # would pin.
    try:
        h_ell = REGISTRY.get("spmv_ell", harness_name)
    except KeyError:
        h_ell = None
    if h_ell is not None and getattr(h_ell, "fuse_epilogue", False):
        from repro.sparse import ell_from_csr
        ell = ell_from_csr(csr)
        vec_full = vec_for(csr)
        ell_binding = {"val": ell.val, "col_ind": ell.col,
                       "vector": vec_full, "rows": csr.rows}
        bias = vec_for(csr)[: ell.val.shape[0]]
        fused_binding = dict(ell_binding)
        fused_binding["bias"] = bias
        best_sched = by_key.get(best_key)
        plain_ctx = CallCtx(mode="host", cache=ctx.cache, format="ELL",
                            platform=plat, schedule=best_sched)
        fused_ctx = CallCtx(mode="host", cache=ctx.cache, format="ELL",
                            platform=plat, schedule=best_sched,
                            epilogue="relu")
        pair = sweep({
            "fused": lambda: h_ell(fused_binding, fused_ctx),
            "unfused": lambda: apply_epilogue(
                h_ell(ell_binding, plain_ctx), bias, "relu"),
        }, reps=max(8, reps), warmup=2)
        if all(t == t for t in pair.values()):
            result["fused_epilogue"] = {
                "computation": "spmv_ell",
                "epilogue": "relu",
                "schedule": best_key,
                "t_fused_s": pair["fused"],
                "t_unfused_s": pair["unfused"],
                "fused_vs_unfused": pair["unfused"] / pair["fused"],
            }
    return result


def _coupled_fn(csr):
    """A @ (A @ v): two spmv matches on the SAME matrix — the coupled
    program whose jointly-optimal assignment can differ from per-match
    winners (one shared repack amortizes over both kernels)."""
    n, nnz = csr.rows, csr.nnz

    def coupled(val, col, row_ptr, v):
        def spmv(x):
            row = jnp.repeat(jnp.arange(n, dtype=jnp.int32),
                             jnp.diff(row_ptr), total_repeat_length=nnz)
            return jax.ops.segment_sum(val * x[col], row, num_segments=n)
        return spmv(spmv(v))

    return coupled


def joint_section(prob_name: str, csr, vec, steady_t: dict,
                  marshal_t: dict, plat: str) -> dict:
    """Grade the joint plan search on this problem's MEASURED components.

    Model arithmetic (CI-noise proof, like the marshal_aware section):
    take the fastest marshal-free kernel (ks) and the fastest
    repack-carrying kernel (ke, repack M), pick the flip-inducing reuse
    r = M / (1.5 * (ks - ke)) — inside the window (M/2delta, M/delta)
    where the per-match argmin picks the marshal-free backend at every
    match but sharing the repack across two matches is cheaper — and run
    the REAL beam search over the resulting two-match cost tables.  Then
    an end-to-end autotuned compile of the coupled program checks the
    pass manager actually runs the search and pins its assignment."""
    from repro.core.plan_search import Candidate, MarshalReq, search

    free = {b: t for b, t in steady_t.items()
            if marshal_t.get(b, 0.0) <= 0.0}
    paid = {b: t for b, t in steady_t.items()
            if marshal_t.get(b, 0.0) > 0.0}
    result: dict = {"eligible": bool(free and paid)}
    if not (free and paid):
        return result
    ks_name = min(free, key=free.get)
    ke_name = min(paid, key=paid.get)
    ks, ke, M = free[ks_name], paid[ke_name], marshal_t[ke_name]
    delta = ks - ke
    # flip-inducing declared call frequency; with no kernel advantage
    # (delta <= 0) no rate flips, so grade at the default rate instead
    reuse = max(1.0, M / (1.5 * delta)) if delta > 0 \
        else lilac.MarshalPolicy().reuse
    try:
        dst = REGISTRY.get("spmv_csr", ke_name).marshal[0].dst
    except Exception:
        dst = "ELL8"
    req = MarshalReq(matrix=prob_name, src="csr_binding", dst=dst,
                     full_s=M)

    def table():
        return [Candidate(ks_name, ks), Candidate(ke_name, ke, reqs=(req,))]

    res = search([table(), table()], reuse=reuse, width=8)
    jvg = (res.greedy_cost / res.cost) if res.cost > 0 else 1.0
    result.update({
        "reuse": reuse,
        "marshal_free_kernel": {ks_name: ks},
        "repack_kernel": {ke_name: ke},
        "marshal_s": M,
        "delta_s": delta,
        "greedy_cost_s": res.greedy_cost,
        "independent_cost_s": res.independent_cost,
        "joint_cost_s": res.cost,
        "joint_assignment": [c.harness for c in res.assignment],
        "joint_vs_greedy": jvg,
        "joint_vs_independent": res.joint_vs_independent,
        "joint_never_slower_than_greedy":
            bool(res.cost <= res.greedy_cost * (1.0 + 1e-9)),
        "flipped": [c.harness for c in res.assignment]
                   == [ke_name, ke_name] and delta > 0,
    })
    emit(f"tab2.{prob_name}.joint", res.cost,
         f"joint_vs_greedy={jvg:.2f}x reuse={reuse:.1f} "
         f"assignment={result['joint_assignment']}")

    # end-to-end: the pass manager's joint pass on the coupled program,
    # warm-started from this sweep's seeded autotune records
    if csr.shape[0] == csr.shape[1]:
        acc = lilac.compile(_coupled_fn(csr), mode="host",
                            policy="autotune", plan_cache="off",
                            marshal_policy=lilac.MarshalPolicy(reuse=reuse))
        acc(csr.val, csr.col_ind, csr.row_ptr, vec)
        entry = next(iter(acc._compiled.values()))
        first = [n for _, n in acc.last_selections]
        acc(csr.val, csr.col_ind, csr.row_ptr, vec)
        result["e2e"] = {
            "matches": len(entry.report.matches),
            "joint_done": bool(entry.joint_done),
            "joint": entry.joint,
            "first_call_selections": first,
            "steady_selections": [n for _, n in acc.last_selections],
        }
    return result


def run(reps: int = 10, quick: bool = False, out: str | None = None,
        max_variants: int = 0, joint: bool = False) -> dict:
    """Two calling contexts per (problem, backend):
    steady — matrix reused across calls (marshaling amortized; the
             PageRank/CG regime), and
    cold   — matrix changes every call (conversion on the critical path;
             the streaming regime).
    The winner flips between contexts and problems — the paper's Table 2
    conclusion (no universally-best backend) in single-platform form."""
    suite = problem_suite(quick=quick)
    plat = jax.default_backend()
    tuner = REGISTRY.autotuner
    table = {}
    best = {}
    report = {
        "benchmark": "tab2_backends",
        "quick": quick,
        "reps": reps,
        "platform": plat,
        "host": _platform.machine(),
        "backends": BACKENDS,
        "default_backend": _default_backend(plat),
        "autotune_cache": str(tuner.cache.path),
        "max_variants": max_variants,
        "problems": {},
        "schedule_sweeps": {},
    }
    for prob_name, csr in suite.items():
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        base = jax.jit(naive)
        t_naive = timeit(base, csr.val, csr.col_ind, csr.row_ptr, vec,
                         reps=reps)
        accs = {}
        for backend in BACKENDS:
            try:
                # bake=False: this sweep measures each backend's
                # kernel+marshal economics through the interpreter (the
                # timings seeded into the autotune cache must not include
                # plan-dispatch effects, and the cold context's
                # cache.clear() must actually force a repack — a baked
                # plan's guards would ignore it).  Plan dispatch has its
                # own benchmark: dispatch_overhead.py.
                accs[backend] = lilac.compile(naive, mode="host",
                                              policy=backend, bake=False)
            except Exception:
                pass
        # steady and cold fail independently: a cold-path exception
        # (repack on the critical path) must not retract the backend's
        # already-measured steady result, or the report's winner and the
        # autotune-cache seed would disagree about the candidate set.
        steady_t = sweep(
            {b: (lambda acc=acc: acc(csr.val, csr.col_ind, csr.row_ptr, vec))
             for b, acc in accs.items()}, reps=reps)

        def cold(acc):
            def fn():
                acc.cache.clear()
                return acc(csr.val, csr.col_ind, csr.row_ptr, vec)
            return fn

        cold_t = sweep({b: cold(acc) for b, acc in accs.items()},
                       reps=max(2, reps // 3), warmup=1)
        row = {}
        abs_t = {"steady": {}, "cold": {}}
        marshal_t = {}
        tune_match = None
        for backend in BACKENDS:
            ts = steady_t.get(backend, float("nan"))
            tc = cold_t.get(backend, float("nan"))
            row[(backend, "steady")] = t_naive / ts
            row[(backend, "cold")] = t_naive / tc
            if ts == ts:
                abs_t["steady"][backend] = ts
            if tc == tc:
                abs_t["cold"][backend] = tc
            acc = accs.get(backend)
            if acc is None or ts != ts:
                continue
            if acc.last_selections and tune_match is None:
                # the detected Match: its binding atoms carry avals, so
                # it keys the same autotune signature that a later
                # policy="autotune" call will compute from live values.
                tune_match = acc.last_selections[0][0]
            # measured conversion-path seconds for this backend's
            # marshal clauses (0.0 for repack-free backends)
            try:
                h = REGISTRY.get(tune_match.computation
                                 if tune_match else "spmv_csr", backend)
                marshal_t[backend] = acc.cache.estimate_marshal_seconds(
                    h.marshal)
            except Exception:
                marshal_t[backend] = 0.0
        table[prob_name] = row
        prob_report = {"t_naive_s": t_naive, "contexts": {}}
        for ctx in ("steady", "cold"):
            cands = [b for b in BACKENDS if row[(b, ctx)] == row[(b, ctx)]]
            winner = max(cands, key=lambda b: row[(b, ctx)])
            best[(prob_name, ctx)] = winner
            cells = " ".join(f"{b}={row[(b, ctx)]:.2f}x" for b in cands)
            emit(f"tab2.{prob_name}.{ctx}", t_naive,
                 f"{cells} best={winner}")
            default = _default_backend(plat)
            t_default = abs_t[ctx].get(default, float("nan"))
            t_tuned = abs_t[ctx][winner]
            prob_report["contexts"][ctx] = {
                "times_s": abs_t[ctx],
                "speedups_vs_naive": {b: row[(b, ctx)] for b in cands},
                "default": default,
                "tuned": winner,
                "t_default_s": t_default,
                "t_tuned_s": t_tuned,
                "tuned_vs_default": (t_default / t_tuned
                                     if t_tuned == t_tuned else float("nan")),
                "tuned_never_slower": bool(t_tuned <= t_default)
                                      if t_default == t_default else True,
            }
        # Marshal-aware vs kernel-only tuning on the steady context: the
        # amortized cost (kernel + repack/reuse at the declared call
        # frequency) of the marshal-aware argmin is, by construction, never
        # worse than the kernel-only argmin's — surfaced per problem so the
        # acceptance gate can assert it.
        from repro.core.autotune import Autotuner
        reuse = lilac.MarshalPolicy().reuse
        if abs_t["steady"]:
            amort = Autotuner.amortized(abs_t["steady"], marshal_t, reuse)
            kernel_winner = min(abs_t["steady"], key=abs_t["steady"].get)
            marshal_winner = min(amort, key=amort.get)
            prob_report["marshal_aware"] = {
                "reuse": reuse,
                "marshal_s": marshal_t,
                "amortized_s": amort,
                "tuned_kernel_only": kernel_winner,
                "tuned_with_marshal_cost": marshal_winner,
                "never_slower": bool(
                    amort[marshal_winner] <= amort[kernel_winner]),
            }
            emit(f"tab2.{prob_name}.marshal_aware", amort[marshal_winner],
                 f"kernel_only={kernel_winner} "
                 f"with_marshal_cost={marshal_winner}")
        # Per-schedule kernel sweeps: the variant space the autotuner
        # searches, measured exhaustively (up to --max-variants) so the
        # report shows what sweeping buys over each kernel's old
        # fixed-constant schedule.
        sweeps = {}
        for hname in SCHEDULE_HARNESSES:
            sw = schedule_sweep(csr, vec, hname, max(2, reps // 3),
                                max_variants, plat)
            if sw is not None:
                sweeps[hname] = sw
                emit(f"tab2.{prob_name}.schedule.{hname}",
                     sw["t_best_schedule_s"],
                     f"best={sw['best_schedule']} "
                     f"{sw['swept_vs_default_schedule']:.2f}x over default"
                     + (f"; fused_epilogue "
                        f"{sw['fused_epilogue']['fused_vs_unfused']:.2f}x"
                        if "fused_epilogue" in sw else ""))
        if sweeps:
            report["schedule_sweeps"][prob_name] = sweeps
        # Seed the persistent autotune cache from the steady-state sweep
        # (kernel + marshal measurements): this run IS the measurement, so
        # a later policy="autotune" process selects the amortized winner
        # here with zero re-timing.
        # (no schedules= argument: the seeded record is a kernel-level
        # decision over the jnp.* backends — on a platform where
        # variant-declaring candidates enter the pool, the tuner treats it
        # as a prior and re-sweeps rather than serving it stale)
        if tune_match is not None and abs_t["steady"]:
            m = tune_match
            tuned = tuner.record_external(m.computation, m.format, plat,
                                          "host", m.binding, abs_t["steady"],
                                          marshal_s=marshal_t, reuse=reuse)
            prob_report["autotune_signature"] = signature_of(
                m.computation, m.format, plat, m.binding)
            prob_report["autotune_recorded"] = tuned
        # joint plan search grading rides the seeded records above (the
        # e2e coupled compile warm-starts from them with zero re-timing)
        if joint and abs_t["steady"]:
            prob_report["joint_search"] = joint_section(
                prob_name, csr, vec, abs_t["steady"], marshal_t, plat)
        report["problems"][prob_name] = prob_report
    emit("tab2.distinct_winners", 0.0,
         f"n={len(set(best.values()))} of {len(BACKENDS)} backends win in "
         f"some (problem x context) cell")
    report["distinct_winners"] = len(set(best.values()))
    report["tuned_never_slower_everywhere"] = all(
        c["tuned_never_slower"]
        for p in report["problems"].values() for c in p["contexts"].values())
    report["tuned_with_marshal_cost_never_slower_everywhere"] = all(
        p.get("marshal_aware", {}).get("never_slower", True)
        for p in report["problems"].values())
    report["tuned_schedule_never_slower_than_default_schedule"] = all(
        sw["tuned_schedule_never_slower_than_default_schedule"]
        for sweeps in report["schedule_sweeps"].values()
        for sw in sweeps.values())
    swept_wins = [sw["swept_vs_default_schedule"]
                  for sweeps in report["schedule_sweeps"].values()
                  for sw in sweeps.values()]
    report["best_swept_vs_default_schedule"] = (
        float(np.max(swept_wins)) if swept_wins else float("nan"))
    report["problems_with_swept_schedule_win_1_2x"] = int(sum(
        w >= 1.2 for w in swept_wins))
    fused_wins = [sw["fused_epilogue"]["fused_vs_unfused"]
                  for sweeps in report["schedule_sweeps"].values()
                  for sw in sweeps.values() if "fused_epilogue" in sw]
    report["fused_epilogue_always_faster"] = (
        all(w > 1.0 for w in fused_wins) if fused_wins else None)
    # Since schema 4 that is a MEASURED outcome, not an assumption: the
    # autotuner sweeps fused vs unfused per call site and pins only the
    # faster realization, so a False here is handled by the sweep (the
    # unfused variant wins that site) rather than silently regressing.
    report["fused_epilogue_pinned_by_measurement"] = True
    if joint:
        sections = [p["joint_search"] for p in report["problems"].values()
                    if "joint_search" in p]
        elig = [s for s in sections if s.get("eligible")]
        report["joint_never_slower_than_greedy"] = (
            all(s["joint_never_slower_than_greedy"] for s in elig)
            if elig else None)
        report["joint_beats_greedy_somewhere"] = any(
            s["joint_vs_greedy"] > 1.0 for s in elig)
        report["best_joint_vs_greedy"] = (
            max(s["joint_vs_greedy"] for s in elig)
            if elig else float("nan"))
        report["joint_e2e_all_searched"] = all(
            s.get("e2e", {}).get("joint_done", False)
            for s in elig if "e2e" in s) if elig else None
    # End-to-end proof that the cache is live: a fresh autotune-policy pass
    # over the last problem must select from the cache without re-timing.
    timing_before = tuner.stats.timing_calls
    acc = lilac.compile(naive, mode="host", policy="autotune")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    report["warm_start"] = {
        "selected": acc.last_selections[0][1] if acc.last_selections else None,
        "schedule": acc.last_schedules[0] if acc.last_schedules else None,
        "re_timed_candidates": tuner.stats.timing_calls - timing_before,
    }
    if out:
        write_json_report(out, report)
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke grid: small problems, few reps")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--max-variants", type=int, default=None,
                    help="cap per-harness schedule variants swept "
                         "(default: 8 in --quick, unlimited otherwise)")
    ap.add_argument("--out", default="BENCH_tab2_backends.json",
                    help="JSON report path ('' to skip)")
    ap.add_argument("--joint", action="store_true",
                    help="grade the joint whole-program plan search "
                         "(coupled two-match programs + e2e compile)")
    args = ap.parse_args(argv)
    reps = args.reps if args.reps is not None else (3 if args.quick else 10)
    mv = args.max_variants if args.max_variants is not None \
        else (8 if args.quick else 0)
    run(reps=reps, quick=args.quick, out=args.out or None, max_variants=mv,
        joint=args.joint)


if __name__ == "__main__":
    main()
