"""§Roofline: derive the three roofline terms per (arch x shape) from the
dry-run's compiled artifacts (experiments/dryrun/*.json).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
All parsed quantities are loop-corrected per-device numbers (see
launch/dryrun.analyze_hlo).  CPU-backend caveat: XLA:CPU upcasts bf16 to
f32 before some collectives; raw terms are reported as parsed, and a
bf16-corrected collective estimate (x0.5 on f32 collective bytes) is shown
alongside.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one new token per sequence
    "long_500k": 1,
}
TRAIN_FLOP_FACTOR = {"train_4k": 6, "prefill_32k": 2,
                     "decode_32k": 2, "long_500k": 2}


def load_cells(jobs_dir: str = "experiments/dryrun",
               mesh: str = "single") -> List[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(jobs_dir, f"*__{mesh}.json"))):
        cells.append(json.load(open(f)))
    return cells


def roofline_row(cell: dict) -> Optional[dict]:
    if cell.get("status") != "ok":
        return None
    n_dev = cell["n_devices"]
    flops_dev = cell["flops"]
    # HBM traffic estimate: >=1MB tensors x2 (r+w); small per-step scan
    # values are VMEM-resident on the TPU target
    bytes_dev = cell.get("bytes_hbm_est", cell["bytes_proxy"])
    coll_dev = cell["collectives"]["total_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    t_coll_bf16 = t_coll * 0.5   # CPU-backend f32-upcast correction bound
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll_bf16}
    dominant = max(terms, key=terms.get)
    model_flops = (TRAIN_FLOP_FACTOR[cell["shape"]]
                   * cell["params_active"] * SHAPE_TOKENS[cell["shape"]])
    hlo_flops_global = flops_dev * n_dev
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    # roofline fraction: ideal time (model flops at peak) / achievable time
    t_ideal = model_flops / (n_dev * PEAK_FLOPS)
    t_bound = max(terms.values())
    frac = t_ideal / t_bound if t_bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "t_collective_bf16_s": t_coll_bf16,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "suggestion": _suggestion(cell, dominant, useful),
    }


def _suggestion(cell, dominant, useful) -> str:
    if dominant == "collective":
        return ("cut collective bytes: bf16 collectives, sequence-parallel "
                "AG/RS instead of AR, fewer FSDP regathers per microbatch")
    if dominant == "memory":
        if cell["shape"] in ("decode_32k", "long_500k"):
            return ("decode is weight/KV-bound: quantize KV cache to int8 "
                    "and batch more requests per step")
        return "raise arithmetic intensity: larger fused blocks, less remat"
    if useful < 0.5:
        return ("compute-bound but wasteful: reduce remat recompute, skip "
                "masked attention blocks, lower MoE capacity factor")
    return "near compute roofline: overlap remaining collectives"


def run(jobs_dir: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for cell in load_cells(jobs_dir, "single"):
        r = roofline_row(cell)
        if r is None:
            print(f"roofline.{cell['arch']}.{cell['shape']},0.0,"
                  f"SKIP({cell.get('reason', cell.get('status'))[:60]})")
            continue
        rows.append(r)
        print(f"roofline.{r['arch']}.{r['shape']},0.0,"
              f"compute={r['t_compute_s']:.3f}s memory={r['t_memory_s']:.3f}s "
              f"collective={r['t_collective_bf16_s']:.3f}s "
              f"dominant={r['dominant']} useful={r['useful_flop_ratio']:.2f} "
              f"roofline_frac={r['roofline_fraction']:.3f}")
    return rows


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s (bf16-corr) "
           "| dominant | MODEL/HLO flops | roofline frac | next lever |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_bf16_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_flop_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['suggestion'][:58]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
