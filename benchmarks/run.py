"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only fig15,...] [--fast]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig15,fig16,tab2,fig18,tab3,"
                         "dispatch,roofline,kernels")
    ap.add_argument("--fast", action="store_true",
                    help="fewer reps (CI mode)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    failures = 0
    if want("tab3"):
        from benchmarks import tab3_detection
        failures += _run("tab3", tab3_detection.run)
    if want("fig15"):
        from benchmarks import fig15_speedup
        failures += _run("fig15", fig15_speedup.run,
                         reps=2 if args.fast else 5)
    if want("fig16"):
        from benchmarks import fig16_expert
        failures += _run("fig16", fig16_expert.run,
                         reps=3 if args.fast else 10)
    if want("tab2"):
        from benchmarks import tab2_backends
        failures += _run("tab2", tab2_backends.run,
                         reps=3 if args.fast else 10)
    if want("fig18"):
        from benchmarks import fig18_marshaling
        failures += _run("fig18", fig18_marshaling.run,
                         reps=2 if args.fast else 5)
    if want("dispatch"):
        from benchmarks import dispatch_overhead
        failures += _run("dispatch", dispatch_overhead.run,
                         reps=30 if args.fast else 100,
                         quick=args.fast)
    if want("kernels"):
        from benchmarks import kernel_analysis
        failures += _run("kernels", kernel_analysis.run)
    if want("roofline"):
        from benchmarks import roofline
        failures += _run("roofline", roofline.run)
    sys.exit(1 if failures else 0)


def _run(name, fn, **kw):
    try:
        fn(**kw)
        return 0
    except Exception:
        print(f"{name}.ERROR,0.0,{traceback.format_exc(limit=2)!r}")
        return 1


if __name__ == "__main__":
    main()
