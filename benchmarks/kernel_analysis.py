"""Kernel-level analysis of the Pallas TPU kernels: VMEM working set,
arithmetic intensity, and the roofline regime each kernel lands in on v5e.

The 40-cell dry-run lowers jnp harnesses (DESIGN.md §7.1); this is the
structural analysis of the hand-tiled kernels themselves, from their
BlockSpecs (no hardware needed — the numbers are exact functions of the
tiling)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
VMEM = 16 * 2 ** 20     # ~16 MiB usable (half of 32 for double buffering)


def _analyze(name, *, flops_per_step, hbm_bytes_per_step, vmem_bytes,
             notes=""):
    intensity = flops_per_step / max(hbm_bytes_per_step, 1)
    ridge = PEAK_FLOPS / HBM_BW   # ~240 flops/byte on v5e
    regime = "compute-bound" if intensity >= ridge else "memory-bound"
    attainable = min(PEAK_FLOPS, intensity * HBM_BW)
    emit(f"kernels.{name}", 0.0,
         f"vmem={vmem_bytes/2**10:.0f}KiB({'OK' if vmem_bytes < VMEM else 'OVER'}) "
         f"intensity={intensity:.1f}flop/B ridge={ridge:.0f} {regime} "
         f"attainable={attainable/1e12:.1f}TF/s "
         f"({attainable/PEAK_FLOPS*100:.0f}% of peak) {notes}")


def run() -> None:
    # bsr_spmm: (bm,bk)x(bk,bn) f32 tiles, block density d
    bm = bk = bn = 128
    _analyze(
        "bsr_spmm.128x128",
        flops_per_step=2 * bm * bk * bn,
        # per step: one stored tile + one rhs tile stream in; out revisited
        hbm_bytes_per_step=(bm * bk + bk * bn) * 4,
        vmem_bytes=(bm * bk + bk * bn + bm * bn) * 4,
        notes="MXU-aligned; out-block reuse across k amortizes the write",
    )
    # bf16 variant doubles intensity
    _analyze(
        "bsr_spmm.128x128.bf16",
        flops_per_step=2 * bm * bk * bn,
        hbm_bytes_per_step=(bm * bk + bk * bn) * 2,
        vmem_bytes=(bm * bk + bk * bn) * 2 + bm * bn * 4,
    )
    # spmv_ell: R x W slab + resident vector; SpMV is memory-bound by nature
    R, W, V = 256, 256, 65536
    _analyze(
        "spmv_ell.256x256",
        flops_per_step=2 * R * W,
        hbm_bytes_per_step=(R * W) * (4 + 4),   # val + col stream; vec resident
        vmem_bytes=(R * W) * 8 + V * 4 + R * 4,
        notes=f"vector {V} f32 resident; gather stays on-chip",
    )
    # windowed variant for huge vectors
    Wn = 65536
    _analyze(
        "spmv_ell.windowed",
        flops_per_step=2 * R * W,
        hbm_bytes_per_step=(R * W) * 8,
        vmem_bytes=(R * W) * 8 + Wn * 4 + R * 4,
        notes="window slice resident instead of full vector",
    )
    # moe_gmm: (tm,dk)x(dk,fn) bf16, weight tile revisited per m-tile
    tm = dk = fn = 128
    _analyze(
        "moe_gmm.128",
        flops_per_step=2 * tm * dk * fn,
        hbm_bytes_per_step=(tm * dk + dk * fn) * 2,
        vmem_bytes=(tm * dk + dk * fn) * 2 + tm * fn * 4,
        notes="group-aligned; expert weight DMA steered by scalar prefetch",
    )
    # decode-regime gmm (tm=8 tokens): weight-streaming bound
    tm2 = 8
    _analyze(
        "moe_gmm.decode_tm8",
        flops_per_step=2 * tm2 * dk * fn,
        hbm_bytes_per_step=(tm2 * dk + dk * fn) * 2,
        vmem_bytes=(tm2 * dk + dk * fn) * 2 + tm2 * fn * 4,
        notes="decode: weight stream dominates -> memory-bound as expected",
    )


if __name__ == "__main__":
    run()
