"""Paper Table 3: reliability of discovery. Every benchmark's sparse kernel
(written in several syntactic variants, mirroring C/C++/FORTRAN surface
differences) must be detected; dense/negative controls must not produce
sparse matches."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.detect import Detector

ROWS, COLS, NNZ = 64, 48, 200


def _variants():
    rng = np.random.default_rng(0)
    val = jnp.asarray(rng.standard_normal(NNZ).astype(np.float32))
    col = jnp.asarray(rng.integers(0, COLS, NNZ).astype(np.int32))
    row = jnp.asarray(np.sort(rng.integers(0, ROWS, NNZ)).astype(np.int32))
    cuts = np.sort(rng.integers(0, NNZ + 1, ROWS - 1))
    row_ptr = jnp.asarray(np.concatenate([[0], cuts, [NNZ]]).astype(np.int32))
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    val2 = jnp.asarray(rng.standard_normal((ROWS, 8)).astype(np.float32))
    col2 = jnp.asarray(rng.integers(0, COLS, (ROWS, 8)).astype(np.int32))
    perm = jnp.asarray(rng.permutation(ROWS).astype(np.int32))

    def v_csr_repeat(val, col, row_ptr, vec):
        r = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                       total_repeat_length=NNZ)
        return jax.ops.segment_sum(val * vec[col], r, num_segments=ROWS)

    def v_csr_searchsorted(val, col, row_ptr, vec):
        r = jnp.searchsorted(row_ptr, jnp.arange(NNZ, dtype=jnp.int32),
                             side="right").astype(jnp.int32) - 1
        return jax.ops.segment_sum(val * vec[col], r, num_segments=ROWS)

    def v_csr_commuted(val, col, row_ptr, vec):
        r = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                       total_repeat_length=NNZ)
        return jax.ops.segment_sum(vec[col] * val, r, num_segments=ROWS)

    def v_coo_vectorized(val, col, row, vec):
        return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)

    def v_coo_loop(val, col, row, vec):
        def body(j, out):
            return out.at[row[j]].add(val[j] * vec[col[j]])
        return jax.lax.fori_loop(0, NNZ, body, jnp.zeros(ROWS))

    def v_ell(val2, col2, vec):
        return jnp.sum(val2 * vec[col2], axis=1)

    def v_jds(val2, col2, perm, vec):
        acc = jnp.sum(val2 * vec[col2], axis=1)
        return jnp.zeros(ROWS, acc.dtype).at[perm].set(acc)

    def v_dot(a, b):
        return jnp.sum(a * b)

    def v_dot_loop(a, b):
        return jax.lax.fori_loop(0, COLS,
                                 lambda i, acc: acc + a[i] * b[i],
                                 jnp.float32(0))

    def v_gemv(m, v):
        return m @ v

    def v_spmm(val, col, row_ptr, dmat):
        r = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                       total_repeat_length=NNZ)
        return jax.ops.segment_sum(val[:, None] * dmat[col], r,
                                   num_segments=ROWS)

    # negative controls
    def n_softmax(q, k):
        return jax.nn.softmax(q @ k.T)

    def n_layernorm(x):
        mu = x.mean(-1, keepdims=True)
        return (x - mu) / jnp.sqrt(x.var(-1, keepdims=True) + 1e-5)

    a = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    m = jnp.asarray(rng.standard_normal((ROWS, COLS)).astype(np.float32))
    return [
        ("CSR/repeat-idiom (C-style)", v_csr_repeat,
         (val, col, row_ptr, vec), "CSR"),
        ("CSR/searchsorted (C++-style)", v_csr_searchsorted,
         (val, col, row_ptr, vec), "CSR"),
        ("CSR/commuted (FORTRAN-style)", v_csr_commuted,
         (val, col, row_ptr, vec), "CSR"),
        ("COO/vectorized", v_coo_vectorized, (val, col, row, vec), "COO"),
        ("COO/loop", v_coo_loop, (val, col, row, vec), "COO"),
        ("ELL/padded", v_ell, (val2, col2, vec), "ELL"),
        ("JDS/permuted (Parboil)", v_jds, (val2, col2, perm, vec), "JDS"),
        ("dot/vectorized", v_dot, (a, b), "DOT"),
        ("dot/loop", v_dot_loop, (a, b), "DOT"),
        ("gemv/dense", v_gemv, (m, vec), "GEMV"),
        ("SpMM/csr-x-dense", v_spmm,
         (val, col, row_ptr,
          jnp.asarray(rng.standard_normal((COLS, 6)).astype(np.float32))),
         "CSR"),
        ("NEG softmax-attention", n_softmax, (m, m), None),
        ("NEG layernorm", n_layernorm, (m,), None),
    ]


def run() -> dict:
    det = Detector()
    results = {}
    n_pos = n_detected = n_neg = n_clean = 0
    for name, fn, args, want in _variants():
        r = det.detect_fn(fn, *args)
        sparse = [m for m in r.matches
                  if m.computation.startswith("spmv")
                  or m.computation == "moe_ffn"]
        if want is None:
            n_neg += 1
            clean = len(sparse) == 0
            n_clean += clean
            results[name] = "clean" if clean else "FALSE-POSITIVE"
        else:
            n_pos += 1
            got = [m.format for m in r.matches]
            ok = want in got or (want in ("DOT", "GEMV") and r.matches)
            n_detected += bool(ok)
            results[name] = got[0] if got else "MISS"
        emit(f"tab3.{name.replace(' ', '_').replace('/', '.')}", 0.0,
             f"detected={results[name]}")
    emit("tab3.summary", 0.0,
         f"detected {n_detected}/{n_pos} variants; "
         f"{n_clean}/{n_neg} negative controls clean")
    return results


if __name__ == "__main__":
    run()
