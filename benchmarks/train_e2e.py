"""End-to-end sparse-MoE training step: ``lilac.compile(jax.grad(...))``
vs the dense-dispatch baseline (the transform-composition story of
docs/transforms.md, measured).

The composition under test is the one ``make_train_step(lilac_grad=True)``
builds in the real trainer:

* the loss calls an *inner* lilac-compiled MoE block — detection replaces
  the naive dense dispatch (E·T token-expert pairs) with the
  capacity-bucket harness (E·C, C = ceil(T·K/E · cf)), which is natively
  differentiable, so jax.grad pulls the cotangent through the *sparse*
  dispatch: the backward costs E·C too, not E·T;
* the *outer* ``lilac.compile`` wraps the whole ``value_and_grad`` +
  SGD update: the gradient jaxpr is detected/rewritten as a unit and —
  once resolved — baked into one jitted ExecutablePlan, so steady-state
  training dispatch is a guard check + one jitted call.

Reported gates (CI bench-smoke):

  speedup                     lilac step time / dense step time > 1
  grads_match_dense_oracle    max rel grad err vs jax.jit(dense) < tol
  baked                       the train step reached a baked plan

Routing is balanced (idx = arange % E) so no token exceeds capacity and
the capacity-bucket gradients are bit-for-bit the dense oracle's up to
f32 reassociation (tolerance 2e-4 relative).

CLI:
    python benchmarks/train_e2e.py [--quick] [--reps N] [--out PATH]
"""
from __future__ import annotations

import argparse
import platform as _platform

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, write_json_report
from repro import lilac
from repro.models.layers import _moe_naive_2d

GRAD_RTOL = 2e-4
LR = 1e-2


def _problem(quick: bool):
    T, D, F, E, K = (256, 32, 64, 8, 1) if quick else (1024, 64, 128, 8, 1)
    rng = np.random.default_rng(0)
    params = {
        "wg": jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
        "wu": jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
        "wd": jnp.asarray(rng.standard_normal((E, F, D)).astype(np.float32) * .1),
    }
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    gate = jnp.asarray(rng.random((T, K)).astype(np.float32))
    # balanced routing: every expert sees exactly T*K/E tokens, so the
    # capacity buckets (cf=2) never drop — grads match the dense oracle
    idx = jnp.asarray((np.arange(T * K).reshape(T, K) % E).astype(np.int32))
    target = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    return dict(T=T, D=D, F=F, E=E, K=K), params, x, gate, idx, target


def _make_steps(idx, target):
    """(lilac train step, dense-baseline train step, inner LilacFunction)."""
    inner = lilac.compile(_moe_naive_2d)

    def loss_lilac(params, x, gate):
        out = inner(x, gate, idx, params["wg"], params["wu"], params["wd"])
        return jnp.mean((out - target) ** 2)

    def loss_dense(params, x, gate):
        out = _moe_naive_2d(x, gate, idx,
                            params["wg"], params["wu"], params["wd"])
        return jnp.mean((out - target) ** 2)

    def step(loss_fn):
        def train_step(params, x, gate):
            loss, g = jax.value_and_grad(loss_fn)(params, x, gate)
            new = jax.tree.map(lambda p, gi: p - LR * gi, params, g)
            return loss, new
        return train_step

    fast = lilac.compile(step(loss_lilac))
    base = jax.jit(step(loss_dense))
    return fast, base, inner, loss_lilac, loss_dense


def run(reps: int = 20, quick: bool = False, out: str | None = None) -> dict:
    shape, params, x, gate, idx, target = _problem(quick)
    fast, base, inner, loss_lilac, loss_dense = _make_steps(idx, target)

    # gradient oracle check FIRST (before any update moves params)
    _, g_fast = lilac.compile(jax.value_and_grad(loss_lilac))(params, x, gate)
    _, g_ref = jax.jit(jax.value_and_grad(loss_dense))(params, x, gate)
    rel = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))
                           / (jnp.max(jnp.abs(b)) + 1e-12)),
        g_fast, g_ref)
    max_rel = max(jax.tree.leaves(rel))

    # resolve + bake, then steady-state timing
    fast(params, x, gate)
    fast(params, x, gate)
    info = fast.plan_info()
    t_lilac = timeit(lambda *a: fast(*a)[0], params, x, gate, reps=reps)
    t_dense = timeit(lambda *a: base(*a)[0], params, x, gate, reps=reps)

    # a few real optimization steps: loss must go down on both paths
    p_f, p_d = params, params
    hist_f, hist_d = [], []
    for _ in range(5):
        lf, p_f = fast(p_f, x, gate)
        ld, p_d = base(p_d, x, gate)
        hist_f.append(float(lf))
        hist_d.append(float(ld))

    report = {
        "benchmark": "train_e2e",
        "quick": quick,
        "reps": reps,
        "platform": jax.default_backend(),
        "host": _platform.machine(),
        "shape": shape,
        "t_lilac_step_s": t_lilac,
        "t_dense_step_s": t_dense,
        "speedup": t_dense / t_lilac,
        "lilac_faster_than_dense": t_dense / t_lilac > 1.0,
        "grad_max_rel_err": max_rel,
        "grad_rtol": GRAD_RTOL,
        "grads_match_dense_oracle": max_rel < GRAD_RTOL,
        "inner_selected": [n for _, n in inner.last_selections],
        "baked": info["baked"] >= 1 and not info["bake_errors"],
        "bake_errors": info["bake_errors"],
        "loss_lilac": hist_f,
        "loss_dense": hist_d,
        "loss_decreases": hist_f[-1] < hist_f[0] and hist_d[-1] < hist_d[0],
    }
    emit("train_e2e.step", t_lilac,
         f"dense={t_dense * 1e3:.2f}ms lilac={t_lilac * 1e3:.2f}ms "
         f"speedup={report['speedup']:.2f}x grad_err={max_rel:.2e} "
         f"baked={report['baked']}")
    if out:
        write_json_report(out, report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke shape (T=256)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_train_e2e.json",
                    help="JSON report path ('' to skip)")
    args = ap.parse_args(argv)
    reps = args.reps if args.reps is not None else (10 if args.quick else 30)
    run(reps=reps, quick=args.quick, out=args.out or None)


if __name__ == "__main__":
    main()
