"""Execute the fenced python examples in README.md and docs/*.md.

The docs' executable contract (CI-enforced):

* a fence opening with exactly ```` ```python ```` is an **executable
  example** — this runner executes it;
* a fence opening with ```` ```python no-run ```` is an **illustrative
  fragment** (pseudo-library names, elided setup) — skipped, but GitHub
  still syntax-highlights it (linguist keys on the first word);
* blocks in one file share a namespace, in order, so a later example may
  build on an earlier one's imports and values;
* each file runs in a private working directory with private LiLAC cache
  files, so examples neither pollute nor depend on ``~/.cache/lilac``.

Usage::

    python tools/run_doc_examples.py [files...]     # default: README.md docs/*.md

Exit status is non-zero if any block raises; the failing file, block
number and source line are reported.
"""
from __future__ import annotations

import os
import re
import sys
import tempfile
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# opening fence, capturing the info string; blocks end at a bare ```
_FENCE_RE = re.compile(r"^```(\S[^\n]*)?$")


def extract_blocks(text: str):
    """Yield (start_line, info, source) per fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if m and m.group(1):
            info = m.group(1).strip()
            start = i + 1
            j = start
            while j < len(lines) and lines[j].rstrip() != "```":
                j += 1
            yield start + 1, info, "\n".join(lines[start:j])
            i = j + 1
        else:
            i += 1
    return


def runnable_blocks(text: str):
    for line, info, src in extract_blocks(text):
        words = info.split()
        if words and words[0] == "python" and "no-run" not in words[1:]:
            yield line, src


def run_file(path: Path) -> int:
    """Execute a file's examples in one shared namespace; returns the
    number of failing blocks."""
    blocks = list(runnable_blocks(path.read_text(encoding="utf-8")))
    if not blocks:
        print(f"  {path.relative_to(REPO)}: no executable blocks")
        return 0
    ns: dict = {"__name__": "__doc_example__"}
    failures = 0
    for n, (line, src) in enumerate(blocks, 1):
        label = f"{path.relative_to(REPO)}:{line} (block {n}/{len(blocks)})"
        try:
            code = compile(src, f"{path.name}:{line}", "exec")
            exec(code, ns)
            print(f"  ok   {label}")
        except Exception:
            failures += 1
            print(f"  FAIL {label}")
            traceback.print_exc()
    return failures


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    failures = 0
    with tempfile.TemporaryDirectory(prefix="lilac-doc-examples-") as tmp:
        # private caches + cwd per run: examples must not read or write the
        # user-level ~/.cache/lilac state
        os.environ["LILAC_AUTOTUNE_CACHE"] = os.path.join(tmp, "autotune.json")
        os.environ["LILAC_PLAN_CACHE"] = os.path.join(tmp, "plans.json")
        old_cwd = os.getcwd()
        os.chdir(tmp)
        try:
            for f in files:
                print(f"{f.relative_to(REPO)}:")
                failures += run_file(f)
        finally:
            os.chdir(old_cwd)
    if failures:
        print(f"{failures} doc example block(s) failed")
        return 1
    print("all doc examples passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
