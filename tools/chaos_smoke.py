"""Chaos-smoke gate: the whole quick path under active fault injection.

Drives three surfaces with ``LILAC_FAULTS``-style chaos plans active —

1. a **targeted oracle sweep**: every quick-suite problem compiled under a
   combined fault spec (kernel raises, NaN outputs, marshal/tune/bake
   raises, torn cache writes), outputs compared elementwise against the
   un-rewritten reference;
2. ``benchmarks/tab2_backends.py --quick`` — the backend sweep completes
   under chaos;
3. ``benchmarks/serving.py --quick`` — continuous batching completes with
   decode faults poisoning individual requests.

Gates (exit 1 on any failure):

* ``zero_uncontained_exceptions`` — nothing escapes to the caller;
* ``results_match_oracle`` — every sweep output is reference-correct;
* ``quarantines_persisted`` — the incidents the faults provoked are on
  disk for the next process.

Seeds rotate (``--seed``; CI passes the run number) so successive runs
exercise different fault interleavings while each run stays exactly
reproducible.  On any gate failure the exact fixed-seed repro command is
printed (``CHAOS_SEED=<n> python tools/chaos_smoke.py ...``) so the
failing interleaving can be replayed locally without digging the seed
out of CI logs.  All persistent caches are redirected into a scratch
directory: a chaos run must never poison the perf caches other jobs
share.

CLI:
    python tools/chaos_smoke.py [--seed N] [--out PATH] [--skip-benchmarks]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

CHAOS_SPEC = ("kernel_raise:*:0.4,nan_output:*:0.3,marshal_raise:*:0.3,"
              "tune_raise:*:0.4,bake_raise:*:0.4,cache_torn_write:*:0.5")
SERVE_SPEC = "decode_raise:decode:0.1,decode_nan:decode:0.1"


def repro_command(seed: int, out_path: str | None = None,
                  skip_benchmarks: bool = False) -> str:
    """The exact shell command that replays this run's fault interleaving.

    The fault plan is a pure function of (spec, seed), and all caches are
    scratch-redirected, so seed alone pins the whole run.
    """
    cmd = f"CHAOS_SEED={seed} python tools/chaos_smoke.py"
    if out_path:
        cmd += f" --out {out_path}"
    if skip_benchmarks:
        cmd += " --skip-benchmarks"
    return cmd


def _redirect_caches(scratch: str):
    os.environ["LILAC_AUTOTUNE_CACHE"] = os.path.join(scratch,
                                                      "autotune.json")
    os.environ["LILAC_PLAN_CACHE"] = os.path.join(scratch, "plans.json")
    os.environ["LILAC_QUARANTINE_CACHE"] = os.path.join(scratch,
                                                        "quarantine.json")


def oracle_sweep(seed: int) -> dict:
    """Compile + call every quick problem under the combined chaos spec;
    compare against the un-rewritten reference."""
    import numpy as np
    from benchmarks.common import naive_spmv_fn, problem_suite, vec_for
    from repro import lilac
    from repro.core import faults

    out = {"problems": {}, "uncontained": [], "mismatches": [],
           "faults_fired": 0, "quarantines": 0, "fallbacks": 0}
    for name, csr in problem_suite(quick=True).items():
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)
        a = (csr.val, csr.col_ind, csr.row_ptr, vec)
        ref = np.asarray(naive(*a))
        rec = {"fired": 0, "ok": False}
        try:
            with faults.inject(CHAOS_SPEC, seed=seed) as plan:
                fast = lilac.compile(naive, mode="host", policy="autotune")
                got = np.asarray(fast(*a))
                got2 = np.asarray(fast(*a))       # steady state too
            rec["fired"] = len(plan.fired)
            out["faults_fired"] += len(plan.fired)
            info = fast.resilience_info()
            rec["containment"] = info["containment"]
            out["quarantines"] += info["containment"]["quarantines"]
            out["fallbacks"] += info["containment"]["fallbacks"]
            match = (np.allclose(got, ref, atol=2e-4, rtol=2e-4)
                     and np.allclose(got2, ref, atol=2e-4, rtol=2e-4))
            rec["ok"] = bool(match)
            if not match:
                out["mismatches"].append(name)
        except Exception:
            out["uncontained"].append(
                {"problem": name, "traceback": traceback.format_exc()})
        out["problems"][name] = rec
    return out


def benchmark_sweeps(seed: int) -> dict:
    """tab2 + serving quick runs under chaos: completing without an
    exception IS the gate; their own perf gates are not graded here
    (faults legitimately change selections and timings)."""
    from repro.core import faults

    out = {}
    try:
        from benchmarks import tab2_backends
        with faults.inject(CHAOS_SPEC, seed=seed) as plan:
            r = tab2_backends.run(reps=2, quick=True, out=None)
        out["tab2"] = {"ok": True, "fired": len(plan.fired),
                       "problems": len(r.get("problems", r.get("table", {})))}
    except Exception:
        out["tab2"] = {"ok": False, "traceback": traceback.format_exc()}
    try:
        from benchmarks import serving
        with faults.inject(SERVE_SPEC, seed=seed) as plan:
            r = serving.run(quick=True, n_requests=6, out=None)
        res = r["continuous"]["resilience"]
        out["serving"] = {"ok": True, "fired": len(plan.fired),
                          "decode_faults": res["decode_faults"],
                          "fault_evictions": res["fault_evictions"],
                          "finished": r["continuous"]["finished"]}
    except Exception:
        out["serving"] = {"ok": False, "traceback": traceback.format_exc()}
    return out


def run(seed: int = 0, out_path: str | None = None,
        skip_benchmarks: bool = False, scratch: str | None = None) -> dict:
    scratch = scratch or tempfile.mkdtemp(prefix="lilac-chaos-")
    _redirect_caches(scratch)

    report = {"benchmark": "chaos_smoke", "seed": seed,
              "spec": CHAOS_SPEC, "serve_spec": SERVE_SPEC,
              "scratch": scratch}
    report["oracle_sweep"] = oracle_sweep(seed)
    if not skip_benchmarks:
        report["benchmarks"] = benchmark_sweeps(seed)

    sweep = report["oracle_sweep"]
    benches = report.get("benchmarks", {})
    report["zero_uncontained_exceptions"] = (
        not sweep["uncontained"]
        and all(b.get("ok") for b in benches.values()))
    report["results_match_oracle"] = (
        not sweep["mismatches"]
        and all(p["ok"] for p in sweep["problems"].values()))

    # quarantine persistence: the incidents this run provoked must be on
    # disk, readable by a FRESH store (what the next process sees).  A
    # chaos plan that tears cache writes can leave the LAST in-run save
    # truncated on disk — so flush the shared in-memory incident view now
    # that the fault context has exited (the clean-shutdown flush a real
    # process performs), re-merging every record over any torn file.  The
    # torn-file recovery path itself stays covered by
    # tests/test_resilience.py.
    from repro.core.resilience import QuarantineStore, shared_quarantine
    shared_quarantine().save()
    q = QuarantineStore(os.environ["LILAC_QUARANTINE_CACHE"])
    persisted = len(q.active())
    report["quarantine_records_on_disk"] = persisted
    report["quarantines_persisted"] = (
        persisted >= 1 if sweep["quarantines"] else True)

    report["passed"] = (report["zero_uncontained_exceptions"]
                        and report["results_match_oracle"]
                        and report["quarantines_persisted"])
    report["repro_command"] = repro_command(
        seed, out_path, skip_benchmarks=skip_benchmarks)
    print(f"chaos_smoke seed={seed}: fired={sweep['faults_fired']} "
          f"quarantines={sweep['quarantines']} "
          f"fallbacks={sweep['fallbacks']} persisted={persisted}")
    for gate in ("zero_uncontained_exceptions", "results_match_oracle",
                 "quarantines_persisted"):
        print(f"  {gate}: {report[gate]}")
    for u in sweep["uncontained"]:
        print(f"UNCONTAINED in {u['problem']}:\n{u['traceback']}",
              file=sys.stderr)
    for name, b in benches.items():
        if not b.get("ok"):
            print(f"BENCHMARK {name} failed:\n{b.get('traceback')}",
                  file=sys.stderr)
    if not report["passed"]:
        print(f"GATE FAILURE — replay this exact fault interleaving with:\n"
              f"  {report['repro_command']}", file=sys.stderr)
    if out_path:
        from benchmarks.common import write_json_report
        write_json_report(out_path, report)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("CHAOS_SEED", "0") or 0),
                    help="fault-plan seed (CI rotates via run number)")
    ap.add_argument("--out", default="BENCH_chaos.json",
                    help="JSON report path ('' to skip)")
    ap.add_argument("--skip-benchmarks", action="store_true",
                    help="oracle sweep only (fast local check)")
    args = ap.parse_args(argv)
    report = run(seed=args.seed, out_path=args.out or None,
                 skip_benchmarks=args.skip_benchmarks)
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
